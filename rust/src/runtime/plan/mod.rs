//! The sparsity-plan IR: the single module where "what can this step
//! skip" is decided. Everything upstream of the kernels that used to
//! derive structure ad hoc — the per-site [`Skip`] tags, the
//! activation/weight [`Feed`] transforms, the window/run grouping of
//! `[seq]` b0 tracks, the dp=1 degeneration, the pattern validation —
//! lives here, so that the step interpreter *executes* a
//! [`SparsityPlan`] and the [`Kernels`](crate::runtime::step::Kernels)
//! implementations *lower* plan nodes, with neither re-deciding
//! sparsity.
//!
//! Three layers of structure, in decreasing order of staticness:
//!
//! 1. **Static skips** ([`Skip`]): regular row/tile dropout patterns
//!    from the coordinator's per-step draw (paper section III). Known
//!    before the step runs; encoded in the variant extras (b0 bias
//!    scalars for the MLP, `[seq]` b0 tracks for the LSTM) and decoded
//!    here by [`SparsityPlan::per_step`] / [`SparsityPlan::windowed`].
//! 2. **Window/run grouping** ([`FeedRun`]): consecutive timesteps
//!    sharing one draw (`AD_TIME_WINDOW`), which is what lets weight
//!    preparation be paid once per (site, window).
//! 3. **Dynamic masks** ([`DynMask`]): zeros discovered at runtime —
//!    ReLU-dead activation columns, the architecturally-zero LSTM
//!    initial state — that the *backward* GEMMs may additionally skip
//!    (TensorDash-style, arXiv 2009.00748). Dynamic masks ride on the
//!    plan's GEMM nodes ([`TnNode`], [`NtNode`]) and are advisory: a
//!    backend that ignores them is still correct, and a backend that
//!    honors them must not change any observable value (see the
//!    exactness notes on [`DynMask`]).
//!
//! Dynamic masks must never perturb RNG draw order or the dispatch
//! sequence: they are derived from values the forward pass already
//! produced, consume no randomness, and only ever *restrict* work
//! inside a kernel call — the calls themselves (count, order, shapes)
//! are identical with dynamism on or off. That invariant is what keeps
//! loss curves, checkpoint bytes, and dispatch traces bit-identical
//! across `AD_DYN_BWD` settings on the scalar paths.

use anyhow::{bail, Result};

use crate::patterns::{RowPattern, TilePattern};
use crate::runtime::backend::HostTensor;
use crate::runtime::manifest::ArtifactMeta;
use crate::runtime::step::kernels::PreppedWeight;

// ---------------------------------------------------------------------------
// Static structure: Skip and its kept-set view
// ---------------------------------------------------------------------------

/// Structural sparsity of one GEMM operand/axis. A `Skip` describes
/// zeros that are *known before the kernel runs* because they come from
/// a regular dropout pattern, not from data. See the `Kernels` trait
/// docs for the exact contract per method.
#[derive(Clone, Copy, Debug)]
pub enum Skip {
    Dense,
    Rows(RowPattern),
    Tiles(TilePattern),
}

/// The kept set of a [`Skip`] along one axis — the structured answer to
/// "which indices survive": everything, a flat row list, or a tile
/// pattern (which never flattens to per-index form; tile kernels walk
/// the grid via [`TilePattern::kept_tiles`]).
#[derive(Clone, Debug)]
pub enum Kept {
    /// No structure: every index of the axis is kept.
    All,
    /// Kept indices along the axis, ascending.
    Rows(Vec<usize>),
    /// Tile-granular structure over a `[k, n]` weight; per-tile kept
    /// info, not per-index.
    Tiles(TilePattern),
}

impl Skip {
    /// Kept structure along an axis of width `dim`. Total — `Tiles`
    /// returns its pattern instead of panicking; callers that need a
    /// flat index list match on [`Kept::Rows`] and treat the other
    /// arms explicitly.
    pub fn kept(&self, dim: usize) -> Kept {
        match self {
            Skip::Dense => Kept::All,
            Skip::Rows(p) => {
                debug_assert_eq!(p.m, dim, "Rows skip width mismatch");
                Kept::Rows(p.kept_indices())
            }
            Skip::Tiles(t) => Kept::Tiles(*t),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Skip::Dense)
    }
}

// ---------------------------------------------------------------------------
// Dropout-site transforms (the masked-dense form of the compact graphs)
// ---------------------------------------------------------------------------

/// How one dropout site transforms the value it guards. The `skip`
/// fields carry the *structure* of the mask down to the kernels, which
/// is what lets the sparse backend never touch dropped coordinates.
pub enum Feed {
    /// No dropout at this site (layer-0 inputs, eval graphs).
    Plain,
    /// Activation mask + inverted-dropout scale: `conv` (per-element
    /// Bernoulli matrix, `rows == batch`, `skip == Dense`) and `rdp`
    /// (row-pattern keep vector, `rows == 1`, broadcast over the batch,
    /// `skip == Rows`).
    Act { m: Vec<f32>, rows: usize, s: f32, skip: Skip },
    /// Weight mask (`tdp` DropConnect at tile granularity): the matmul
    /// runs against `w ∘ mask` (`skip == Tiles`), the scale applies to
    /// the product.
    Weight { s: f32, skip: Skip },
}

impl Feed {
    /// Structural skip this site contributes to adjacent matmuls.
    pub fn skip(&self) -> Skip {
        match self {
            Feed::Plain => Skip::Dense,
            Feed::Act { skip, .. } | Feed::Weight { skip, .. } => *skip,
        }
    }

    /// Apply an activation mask to `x [b, h]` (no-op for Plain/Weight).
    pub fn mask_act(&self, x: &[f32], b: usize, h: usize) -> Vec<f32> {
        match self {
            Feed::Act { m, rows, s, .. } => {
                let mut out = Vec::with_capacity(b * h);
                for bi in 0..b {
                    let mrow = if *rows == 1 {
                        &m[..h]
                    } else {
                        let r = bi % rows;
                        &m[r * h..(r + 1) * h]
                    };
                    let xrow = &x[bi * h..(bi + 1) * h];
                    for (xv, mv) in xrow.iter().zip(mrow) {
                        out.push(xv * mv * s);
                    }
                }
                out
            }
            _ => x.to_vec(),
        }
    }
}

/// One contiguous run of timesteps sharing a single pattern draw — a
/// *time window* of the unrolled sequence. Timesteps `t0..t1` of the
/// owning site all use `feed`, so weight preparation for the run is
/// paid once and reused across the window's forward, backward, and
/// softmax GEMMs. The per-step default degenerates to one run per site
/// covering `0..seq`.
pub struct FeedRun {
    pub t0: usize,
    pub t1: usize,
    pub feed: Feed,
}

/// Row pattern with input validation (bail, not panic).
pub fn row_pattern_checked(m: usize, dp: usize, b0: usize)
                           -> Result<RowPattern> {
    if dp == 0 || dp > m {
        bail!("rdp: dp={dp} out of range for layer width {m}");
    }
    if b0 >= dp {
        bail!("rdp: bias b0={b0} must be < dp={dp}");
    }
    Ok(RowPattern::new(m, dp, b0))
}

/// Tile pattern with input validation.
pub fn tile_pattern_checked(k: usize, n: usize, dp: usize, b0: usize,
                            tile: usize) -> Result<TilePattern> {
    if dp == 0 {
        bail!("tdp: dp must be >= 1");
    }
    if b0 >= dp {
        bail!("tdp: bias b0={b0} must be < dp={dp}");
    }
    let (tr, tc) = (crate::patterns::pick_block(k, tile),
                    crate::patterns::pick_block(n, tile));
    let (tk, tn) = (k / tr, n / tc);
    if tn % dp != 0 && tk % dp != 0 {
        bail!("tdp: dp={dp} must divide one tile-grid edge of {tk}x{tn} \
               (weight {k}x{n}, tile {tr}x{tc})");
    }
    Ok(TilePattern::new(k, n, dp, b0, tile))
}

// ---------------------------------------------------------------------------
// The plan: per-step, per-site static structure
// ---------------------------------------------------------------------------

/// The per-step sparsity plan: for every dropout site, the windowed
/// [`FeedRun`]s decoded from the variant extras the coordinator front
/// assembled (`push_bias_scalars` / `push_bias_tracks` /
/// `push_scale_scalars`). Built once per executed step; the step
/// interpreter executes it and never re-derives structure.
pub struct SparsityPlan {
    sites: Vec<Vec<FeedRun>>,
}

impl SparsityPlan {
    /// Decode per-step extras (the MLP convention: one b0 scalar — or
    /// conv mask — plus one scale per site) into a single-run-per-site
    /// plan. `widths[i]` is the activation width guarded by site i (for
    /// rdp masks); `wdims[i]` the weight matrix dims guarded by site i
    /// (for tdp masks).
    pub fn per_step(meta: &ArtifactMeta, extras: &[&HostTensor],
                    widths: &[usize], wdims: &[(usize, usize)])
                    -> Result<SparsityPlan> {
        let sites = widths.len();
        check_extras(meta, extras, sites)?;
        let mut out = Vec::with_capacity(sites);
        for i in 0..sites {
            let s = extras[sites + i].as_f32()?[0];
            let feed = match meta.variant.as_str() {
                "conv" => Feed::Act {
                    m: extras[i].as_f32()?.to_vec(),
                    rows: extras[i].shape()[0],
                    s,
                    skip: Skip::Dense,
                },
                "rdp" | "tdp" => {
                    let b0 = extras[i].as_i32()?[0];
                    pattern_feed(meta, i, b0, widths[i], wdims[i], s)?
                }
                other => bail!("step interpreter: unknown variant \
                                '{other}'"),
            };
            out.push(vec![FeedRun { t0: 0, t1: 1, feed }]);
        }
        Ok(SparsityPlan { sites: out })
    }

    /// Decode windowed extras (the LSTM convention: rdp/tdp extras are
    /// `[seq]` i32 b0 tracks — entry `t` is the kept residue for
    /// timestep `t`, constant within each time window — and consecutive
    /// equal entries group into one [`FeedRun`]). The plan is thus
    /// entirely data-driven: the per-step default arrives as a constant
    /// track and produces exactly one run per site, while a windowed
    /// coordinator produces one run per window with no runtime knob
    /// involved. Conv masks are per-step: one run covering the
    /// sequence.
    pub fn windowed(meta: &ArtifactMeta, extras: &[&HostTensor],
                    seq: usize, widths: &[usize],
                    wdims: &[(usize, usize)]) -> Result<SparsityPlan> {
        let sites = widths.len();
        check_extras(meta, extras, sites)?;
        let mut out = Vec::with_capacity(sites);
        for i in 0..sites {
            let s = extras[sites + i].as_f32()?[0];
            match meta.variant.as_str() {
                "conv" => {
                    out.push(vec![FeedRun {
                        t0: 0,
                        t1: seq,
                        feed: Feed::Act {
                            m: extras[i].as_f32()?.to_vec(),
                            rows: extras[i].shape()[0],
                            s,
                            skip: Skip::Dense,
                        },
                    }]);
                }
                "rdp" | "tdp" => {
                    let track = extras[i].as_i32()?;
                    if track.len() != seq {
                        bail!("{}: b0 track for site {i} has {} entries, \
                               seq is {seq}", meta.name, track.len());
                    }
                    let mut runs = Vec::new();
                    let mut t0 = 0;
                    while t0 < seq {
                        let b0 = track[t0];
                        let mut t1 = t0 + 1;
                        while t1 < seq && track[t1] == b0 {
                            t1 += 1;
                        }
                        let feed = pattern_feed(meta, i, b0, widths[i],
                                                wdims[i], s)?;
                        runs.push(FeedRun { t0, t1, feed });
                        t0 = t1;
                    }
                    out.push(runs);
                }
                other => bail!("step interpreter: unknown variant \
                                '{other}'"),
            }
        }
        Ok(SparsityPlan { sites: out })
    }

    /// Number of dropout sites in the plan.
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    /// The windowed runs of site `i` (contiguous, covering the
    /// sequence by construction).
    pub fn runs(&self, i: usize) -> &[FeedRun] {
        &self.sites[i]
    }

    /// Single-run accessor for per-step plans (the MLP shape).
    pub fn feed(&self, i: usize) -> &Feed {
        debug_assert_eq!(self.sites[i].len(), 1,
                         "feed() on a multi-run site");
        &self.sites[i][0].feed
    }

    /// `out[site][t]` -> index of the run covering timestep `t`.
    pub fn run_lookup(&self, seq: usize) -> Vec<Vec<usize>> {
        self.sites
            .iter()
            .map(|rs| {
                let mut v = vec![0usize; seq];
                for (ri, r) in rs.iter().enumerate() {
                    for t in r.t0..r.t1 {
                        v[t] = ri;
                    }
                }
                v
            })
            .collect()
    }
}

fn check_extras(meta: &ArtifactMeta, extras: &[&HostTensor],
                sites: usize) -> Result<()> {
    if extras.len() != 2 * sites {
        bail!("{}: expected {} variant extras, got {}", meta.name,
              2 * sites, extras.len());
    }
    if meta.variant != "conv" && meta.dp.len() != sites {
        bail!("{}: manifest dp {:?} does not cover {} sites", meta.name,
              meta.dp, sites);
    }
    Ok(())
}

/// Build one rdp/tdp [`Feed`] for site `i` from a single `(dp, b0)`
/// draw — shared by the per-step and windowed decoders.
fn pattern_feed(meta: &ArtifactMeta, i: usize, b0: i32, width: usize,
                wdim: (usize, usize), s: f32) -> Result<Feed> {
    if b0 < 0 {
        bail!("{}: negative bias {b0}", meta.variant);
    }
    let dp = meta.dp[i];
    match meta.variant.as_str() {
        "rdp" => {
            let pat = row_pattern_checked(width, dp, b0 as usize)?;
            // dp=1 keeps every unit: no structure for the kernels to
            // exploit (the 1/(1-p) scale still applies through the
            // mask).
            let skip = if dp == 1 {
                Skip::Dense
            } else {
                Skip::Rows(pat)
            };
            Ok(Feed::Act { m: pat.mask(), rows: 1, s, skip })
        }
        "tdp" => {
            let (k, n) = wdim;
            let pat = tile_pattern_checked(k, n, dp, b0 as usize,
                                           meta.tile)?;
            // dp=1 keeps every tile: skip the mask/tile walks.
            let skip = if dp == 1 {
                Skip::Dense
            } else {
                Skip::Tiles(pat)
            };
            Ok(Feed::Weight { s, skip })
        }
        other => bail!("step interpreter: unknown variant '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// Dynamic masks: runtime-discovered zeros for the backward GEMMs
// ---------------------------------------------------------------------------

/// Units (columns of a `[m, n]` activation or gradient buffer)
/// discovered *dead at runtime*: every one of the buffer's `m` rows is
/// exactly zero there. `live` is the intersection of the static kept
/// set with the non-dead columns; `total` is the static kept count the
/// mask refined (for touched/skipped accounting).
///
/// Exactness: a kernel that honors a `DynMask` restricts its work to
/// `live`. For TN gradient accumulation this is bitwise exact by
/// construction — a dead unit contributes only `acc += 0.0 * x` terms,
/// which both the dense loops and the sparse `axpy_panel` already skip
/// elementwise — so honoring the mask skips exactly the terms every
/// backend already skips. For NT input-gradient columns the restriction
/// leaves the dead columns zero instead of computing them; that is only
/// value-preserving when the consumer provably zeroes them anyway
/// (the MLP's ReLU-derivative gate: a unit whose forward activation is
/// zero for every row gates its entire gradient column to zero). The
/// step interpreter attaches NT masks only at gated sites; the LSTM
/// BPTT input gradients have no such gate and never carry one.
pub struct DynMask {
    /// Live column indices, ascending (`live ⊆` static kept set).
    pub live: Vec<usize>,
    /// Static kept count of the axis before dynamic refinement.
    pub total: usize,
}

impl DynMask {
    /// Scan a `[m, n]` buffer for dead columns under the static `skip`
    /// of the same axis. Returns `None` for `Tiles` skips (tile
    /// structure does not flatten to a column list; the tile kernels
    /// keep their static walks). The scan is one pass over data the
    /// caller just materialized and consumes no randomness.
    pub fn scan_cols(x: &[f32], m: usize, n: usize, skip: &Skip)
                     -> Option<DynMask> {
        debug_assert_eq!(x.len(), m * n);
        let mut nonzero = vec![false; n];
        for row in x.chunks(n) {
            for (f, &v) in nonzero.iter_mut().zip(row) {
                *f |= v != 0.0;
            }
        }
        let (live, total) = match skip.kept(n) {
            Kept::All => {
                ((0..n).filter(|&j| nonzero[j]).collect::<Vec<_>>(), n)
            }
            Kept::Rows(kept) => {
                let t = kept.len();
                (kept.into_iter().filter(|&j| nonzero[j]).collect(), t)
            }
            Kept::Tiles(_) => return None,
        };
        Some(DynMask { live, total })
    }

    /// The mask of an architecturally-zero operand — the LSTM's initial
    /// hidden state at `t == 0`, known dead without scanning. Every
    /// column is dropped.
    pub fn zero_state(k: usize) -> DynMask {
        DynMask { live: Vec::new(), total: k }
    }

    /// Columns the mask newly discovered dead.
    pub fn dropped(&self) -> usize {
        self.total - self.live.len()
    }
}

// ---------------------------------------------------------------------------
// GEMM nodes: what the step interpreter hands the kernels
// ---------------------------------------------------------------------------

/// One forward GEMM site of the plan (`C[m,n] = A[m,k] @ B[k,n]`):
/// static structure plus an optional prepared-weight handle.
pub struct GemmNode<'a> {
    /// Structure along the shared dim (`Rows`: A's dropped columns are
    /// exactly zero; `Tiles`: B is tile-masked).
    pub k_skip: Skip,
    /// `Rows`: output columns outside the kept set may be left exactly
    /// zero (the caller masks them before any further use).
    pub out_skip: Skip,
    /// Per-(site, window) prepared weight, when the site preps one.
    pub pw: Option<&'a PreppedWeight>,
}

/// One backward input-gradient GEMM (`C[m,k] = A[m,n] @ B[k,n]^T`).
pub struct NtNode<'a> {
    /// `Rows`: output columns (the k axis) outside the kept set may be
    /// left zero; `Tiles`: B is tile-masked.
    pub skip: Skip,
    /// Prepared weight handle, when the site preps one.
    pub pw: Option<&'a PreppedWeight>,
    /// Dynamically-dead output columns a backend may additionally leave
    /// zero. Attached only where a downstream gate makes that exact
    /// (see [`DynMask`]).
    pub dyn_cols: Option<&'a DynMask>,
}

/// One weight-gradient accumulation (`C[k,n] += A[m,k]^T @ B[m,n]`).
pub struct TnNode<'a> {
    /// `Rows`: A's columns (C's rows) outside the kept set are exactly
    /// zero — dropped gradient rows receive no accumulation. `Tiles`:
    /// only C's kept tiles receive accumulation.
    pub row_skip: Skip,
    /// `Rows`: B's columns (C's columns) outside the kept set are
    /// exactly zero. Never `Tiles`.
    pub col_skip: Skip,
    /// Dynamically-dead gradient rows (dead columns of A) a backend may
    /// skip outright — bitwise exact, see [`DynMask`].
    pub dyn_rows: Option<&'a DynMask>,
}

impl<'a> GemmNode<'a> {
    pub fn new(k_skip: Skip, out_skip: Skip) -> Self {
        GemmNode { k_skip, out_skip, pw: None }
    }

    pub fn with_pw(mut self, pw: &'a PreppedWeight) -> Self {
        self.pw = Some(pw);
        self
    }
}

impl<'a> NtNode<'a> {
    pub fn new(skip: Skip) -> Self {
        NtNode { skip, pw: None, dyn_cols: None }
    }

    pub fn with_pw(mut self, pw: &'a PreppedWeight) -> Self {
        self.pw = Some(pw);
        self
    }

    pub fn with_dyn(mut self, mask: Option<&'a DynMask>) -> Self {
        self.dyn_cols = mask;
        self
    }
}

impl<'a> TnNode<'a> {
    pub fn new(row_skip: Skip, col_skip: Skip) -> Self {
        TnNode { row_skip, col_skip, dyn_rows: None }
    }

    pub fn with_dyn(mut self, mask: Option<&'a DynMask>) -> Self {
        self.dyn_rows = mask;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_kept_is_total() {
        assert!(matches!(Skip::Dense.kept(8), Kept::All));
        let r = Skip::Rows(RowPattern::new(8, 2, 1));
        match r.kept(8) {
            Kept::Rows(v) => assert_eq!(v, vec![1, 3, 5, 7]),
            other => panic!("expected Rows, got {other:?}"),
        }
        assert!(!r.is_dense());
        assert!(Skip::Dense.is_dense());
        // Tiles: structured kept-tile info instead of the old panic.
        let t = Skip::Tiles(TilePattern::new(32, 64, 2, 0, 16));
        match t.kept(32) {
            Kept::Tiles(pat) => {
                assert_eq!(pat.kept_tiles().len(), pat.kept_count());
            }
            other => panic!("expected Tiles, got {other:?}"),
        }
    }

    #[test]
    fn row_and_tile_pattern_validation() {
        assert!(row_pattern_checked(8, 2, 1).is_ok());
        assert!(row_pattern_checked(8, 2, 2).is_err());
        assert!(row_pattern_checked(8, 0, 0).is_err());
        assert!(tile_pattern_checked(32, 64, 2, 0, 16).is_ok());
        assert!(tile_pattern_checked(32, 64, 2, 2, 16).is_err());
        // dp=3 divides neither 32/16=2 nor 64/16=4.
        assert!(tile_pattern_checked(32, 64, 3, 0, 16).is_err());
    }

    #[test]
    fn act_feed_masks_and_scales() {
        let f = Feed::Act {
            m: vec![1.0, 0.0],
            rows: 1,
            s: 2.0,
            skip: Skip::Rows(RowPattern::new(2, 2, 0)),
        };
        let out = f.mask_act(&[1.0, 1.0, 3.0, 4.0], 2, 2);
        assert_eq!(out, vec![2.0, 0.0, 6.0, 0.0]);
        assert!(matches!(f.skip(), Skip::Rows(_)));
        let plain = Feed::Plain.mask_act(&[1.0, 2.0], 1, 2);
        assert_eq!(plain, vec![1.0, 2.0]);
        assert!(Feed::Plain.skip().is_dense());
    }

    #[test]
    fn dyn_mask_scans_dead_columns_under_static_skip() {
        // [2, 4] buffer: column 1 dead, column 3 dead.
        let x = [1.0, 0.0, 2.0, 0.0,
                 3.0, 0.0, 0.5, 0.0f32];
        let m = DynMask::scan_cols(&x, 2, 4, &Skip::Dense).unwrap();
        assert_eq!(m.live, vec![0, 2]);
        assert_eq!((m.total, m.dropped()), (4, 2));
        // Static Rows skip: live is intersected with the kept set.
        let sk = Skip::Rows(RowPattern::new(4, 2, 1)); // keeps {1, 3}
        let m = DynMask::scan_cols(&x, 2, 4, &sk).unwrap();
        assert!(m.live.is_empty());
        assert_eq!((m.total, m.dropped()), (2, 2));
        // Tiles: no flat column view — no mask.
        let t = Skip::Tiles(TilePattern::new(4, 4, 2, 0, 2));
        assert!(DynMask::scan_cols(&x, 2, 4, &t).is_none());
        // Zero-state: everything dropped, nothing scanned.
        let z = DynMask::zero_state(7);
        assert_eq!((z.live.len(), z.total, z.dropped()), (0, 7, 7));
    }

    #[test]
    fn node_builders_carry_structure() {
        let sk = Skip::Rows(RowPattern::new(8, 2, 0));
        let pw = PreppedWeight::dense();
        let g = GemmNode::new(sk, Skip::Dense).with_pw(&pw);
        assert!(g.pw.is_some() && !g.k_skip.is_dense());
        let mask = DynMask::zero_state(8);
        let nt = NtNode::new(sk).with_dyn(Some(&mask));
        assert_eq!(nt.dyn_cols.unwrap().dropped(), 8);
        let tn = TnNode::new(Skip::Dense, sk).with_dyn(None);
        assert!(tn.dyn_rows.is_none() && tn.col_skip.is_dense());
    }
}
