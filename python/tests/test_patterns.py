"""Pattern index math: static shapes across biases, partition properties,
gather/mask consistency — must mirror rust/src/patterns exactly."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import patterns


@given(m=st.sampled_from([16, 64, 100, 2048]),
       dp=st.sampled_from([1, 2, 3, 4, 8]))
@settings(max_examples=30, deadline=None)
def test_row_kept_count_static_across_bias(m, dp):
    if dp > m:
        return
    counts = set()
    for b0 in range(dp):
        idx = patterns.row_kept_indices(dp, jnp.int32(b0),
                                        patterns.row_kept_count(m, dp))
        counts.add(int(idx.shape[0]))
        assert int(idx.max()) < m
    assert len(counts) == 1


def test_row_biases_partition():
    m, dp = 64, 4
    covered = np.zeros(m, np.int32)
    for b0 in range(dp):
        mask = np.asarray(patterns.row_mask(m, dp, jnp.int32(b0)))
        covered += mask.astype(np.int32)
    np.testing.assert_array_equal(covered, np.ones(m, np.int32))


def test_gather_matches_mask_semantics():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    dp, b0 = 4, jnp.int32(2)
    wc = patterns.gather_cols(w, dp, b0)
    np.testing.assert_array_equal(np.asarray(wc), np.asarray(w)[:, 2::4])
    wr = patterns.gather_rows(w, 2, jnp.int32(1))
    np.testing.assert_array_equal(np.asarray(wr), np.asarray(w)[1::2])
    v = jnp.arange(12.0)
    np.testing.assert_array_equal(
        np.asarray(patterns.gather_vec(v, 3, jnp.int32(0))),
        np.arange(12.0)[0::3])


def test_scatter_rows_inverse_of_gather():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    dp, b0 = 3, jnp.int32(1)
    rowsc = patterns.gather_rows(w, dp, b0)
    back = patterns.scatter_rows(rowsc, 24, dp, b0)
    mask = np.asarray(patterns.row_mask(24, dp, b0))[:, None]
    np.testing.assert_allclose(np.asarray(back), np.asarray(w) * mask)


@pytest.mark.parametrize("k,n,dp", [(128, 128, 2), (128, 128, 4),
                                    (1024, 64, 8), (784, 2048, 4)])
def test_tile_kept_static_and_partition(k, n, dp):
    cnt = patterns.tile_kept_count(k, n, dp)
    tr, tc = patterns.tile_dims(k, n)
    tk, tn = k // tr, n // tc
    seen = np.zeros((tk, tn), np.int32)
    for b0 in range(dp):
        rows, cols = patterns.tile_kept_rc(k, n, dp, jnp.int32(b0))
        assert rows.shape[0] == cnt
        seen[np.asarray(rows), np.asarray(cols)] += 1
    np.testing.assert_array_equal(seen, np.ones((tk, tn), np.int32))


def test_tile_mask_density():
    k, n, dp = 128, 128, 4
    m = np.asarray(patterns.tile_mask(k, n, dp, jnp.int32(1)))
    assert abs(m.mean() - 1.0 / dp) < 1e-6


def test_tile_dims_adapts():
    assert patterns.tile_dims(784, 2048) == (28, 32)
    assert patterns.tile_dims(64, 10) == (32, 10)
    assert patterns.tile_dims(2048, 2048) == (32, 32)


def test_rust_python_convention_pin():
    # Golden values shared with rust/src/patterns tests: if either side
    # changes its index math, this cross-language pin must be updated in
    # BOTH places (see rust/src/patterns/row.rs example_from_paper).
    idx = patterns.row_kept_indices(3, jnp.int32(0), 3)
    np.testing.assert_array_equal(np.asarray(idx), [0, 3, 6])
    rows, cols = patterns.tile_kept_rc(96, 64, 2, jnp.int32(0))
    kept = sorted(zip(np.asarray(rows).tolist(),
                      np.asarray(cols).tolist()))
    # grid 3x2, keep (r, c) with (c - r) % 2 == 0
    assert kept == [(0, 0), (1, 1), (2, 0)]
