//! Per-iteration pattern scheduling (paper section III-D).
//!
//! A `Schedule` owns one pattern distribution per dropout site (produced by
//! the SGD-based search for that site's target rate) and samples the
//! iteration's `(dp, b0)` choices. In `shared_dp` mode one divisor is
//! drawn for all sites (biases stay independent) — used for architectures
//! whose artifact set only covers equal-dp combinations; per-unit drop
//! statistics are unchanged (the bias, not the divisor, carries the
//! per-unit uniformity).

use anyhow::{bail, Result};

use crate::patterns::{Choice, PatternDistribution};
use crate::search::{self, SearchConfig};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Conv,
    Rdp,
    Tdp,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Conv => "conv",
            Variant::Rdp => "rdp",
            Variant::Tdp => "tdp",
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "conv" | "conventional" => Variant::Conv,
            "rdp" | "row" => Variant::Rdp,
            "tdp" | "tile" => Variant::Tdp,
            other => bail!("unknown dropout variant '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Schedule {
    pub variant: Variant,
    /// Target dropout rate per site.
    pub rates: Vec<f64>,
    /// Distribution K per site (empty for the conventional baseline).
    pub dists: Vec<PatternDistribution>,
    pub shared_dp: bool,
}

impl Schedule {
    /// Build a schedule, running Algorithm 1 once per distinct rate.
    pub fn new(variant: Variant, rates: &[f64], support: &[usize],
               shared_dp: bool) -> Result<Schedule> {
        if shared_dp && rates.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9) {
            bail!("shared_dp requires equal per-site rates, got {rates:?}");
        }
        let dists = match variant {
            Variant::Conv => vec![],
            _ => {
                let cfg = SearchConfig::default();
                rates
                    .iter()
                    .map(|&p| search::search(p, support, &cfg).distribution)
                    .collect()
            }
        };
        Ok(Schedule { variant, rates: rates.to_vec(), dists, shared_dp })
    }

    pub fn sites(&self) -> usize {
        self.rates.len()
    }

    /// Sample the iteration's choices, one per site.
    pub fn sample(&self, rng: &mut Rng) -> Vec<Choice> {
        match self.variant {
            Variant::Conv => vec![Choice::none(); self.sites()],
            _ if self.shared_dp => {
                let dp = self.dists[0].sample(rng).dp;
                (0..self.sites())
                    .map(|_| Choice { dp, b0: rng.next_usize(dp) })
                    .collect()
            }
            _ => self.dists.iter().map(|d| d.sample(rng)).collect(),
        }
    }

    /// Every dp combination this schedule can sample — the artifact names
    /// the executor cache should pre-compile.
    pub fn dp_combos(&self) -> Vec<Vec<usize>> {
        match self.variant {
            Variant::Conv => vec![],
            _ if self.shared_dp => live_support(&self.dists[0])
                .into_iter()
                .map(|dp| vec![dp; self.sites()])
                .collect(),
            _ => {
                // Cartesian product of per-site live supports.
                let mut combos: Vec<Vec<usize>> = vec![vec![]];
                for dist in &self.dists {
                    let live = live_support(dist);
                    let mut next =
                        Vec::with_capacity(combos.len() * live.len());
                    for c in &combos {
                        for &dp in &live {
                            let mut c2 = c.clone();
                            c2.push(dp);
                            next.push(c2);
                        }
                    }
                    combos = next;
                }
                combos
            }
        }
    }
}

/// Divisors carrying non-negligible probability mass in `d`.
fn live_support(d: &PatternDistribution) -> Vec<usize> {
    d.support
        .iter()
        .zip(&d.probs)
        .filter(|(_, &p)| p > 1e-4)
        .map(|(&s, _)| s)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_schedule_has_no_dists() {
        let s = Schedule::new(Variant::Conv, &[0.5, 0.5], &[1, 2, 4],
                              false).unwrap();
        assert!(s.dists.is_empty());
        let mut rng = Rng::new(0);
        assert_eq!(s.sample(&mut rng), vec![Choice::none(); 2]);
        assert!(s.dp_combos().is_empty());
    }

    #[test]
    fn rdp_schedule_hits_rates() {
        let s = Schedule::new(Variant::Rdp, &[0.3, 0.7], &[1, 2, 4, 8],
                              false).unwrap();
        assert!((s.dists[0].expected_rate() - 0.3).abs() < 5e-3);
        assert!((s.dists[1].expected_rate() - 0.7).abs() < 5e-3);
    }

    #[test]
    fn shared_dp_requires_equal_rates() {
        assert!(Schedule::new(Variant::Rdp, &[0.3, 0.7], &[1, 2], true)
            .is_err());
        let s = Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2, 4], true)
            .unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let cs = s.sample(&mut rng);
            assert_eq!(cs[0].dp, cs[1].dp, "shared dp");
        }
    }

    #[test]
    fn biases_independent_even_when_shared() {
        let s = Schedule::new(Variant::Rdp, &[0.7, 0.7], &[8], true)
            .unwrap();
        let mut rng = Rng::new(2);
        let mut differ = 0;
        for _ in 0..200 {
            let cs = s.sample(&mut rng);
            if cs[0].b0 != cs[1].b0 {
                differ += 1;
            }
        }
        assert!(differ > 100, "biases should differ most of the time");
    }

    #[test]
    fn dp_combos_cover_sampling() {
        let s = Schedule::new(Variant::Tdp, &[0.5, 0.5], &[1, 2, 4],
                              false).unwrap();
        let combos = s.dp_combos();
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let cs = s.sample(&mut rng);
            let dp: Vec<usize> = cs.iter().map(|c| c.dp).collect();
            assert!(combos.contains(&dp), "sampled {dp:?} not in combos");
        }
    }

    #[test]
    fn shared_combos_are_diagonal() {
        let s = Schedule::new(Variant::Rdp, &[0.7, 0.7], &[1, 2, 4, 8],
                              true).unwrap();
        for combo in s.dp_combos() {
            assert_eq!(combo[0], combo[1]);
        }
    }
}
