"""AOT export: lower every (model, variant, dp) training graph to HLO text.

Run once via ``make artifacts``. Interchange is HLO *text*, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Output layout:

    artifacts/<name>.hlo.txt      one per executable
    artifacts/manifest.json       machine-readable index driving rust/runtime

The manifest records, per artifact, the exact input/output tensor order,
shapes, dtypes and semantic kinds (param / momentum / data / mask / scale /
bias-scalar / lr), so the Rust coordinator is completely generic over
variants and architectures.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DP_SUPPORT = [1, 2, 4, 8]  # divisor support set; see DESIGN.md section 9


# ---------------------------------------------------------------------------
# Spec plumbing
# ---------------------------------------------------------------------------

@dataclass
class TensorSpec:
    name: str
    shape: tuple
    dtype: str   # "f32" | "i32"
    kind: str    # param|momentum|x|y|mask|scale|bias|lr|loss|correct

    def sds(self):
        dt = {"f32": jnp.float32, "i32": jnp.int32}[self.dtype]
        return jax.ShapeDtypeStruct(tuple(self.shape), dt)

    def js(self):
        return {"name": self.name, "shape": list(self.shape),
                "dtype": self.dtype, "kind": self.kind}


@dataclass
class ArtifactSpec:
    name: str
    fn: object
    inputs: list
    outputs: list
    meta: dict = field(default_factory=dict)

    def js(self):
        return {
            "name": self.name,
            "file": f"{self.name}.hlo.txt",
            "inputs": [t.js() for t in self.inputs],
            "outputs": [t.js() for t in self.outputs],
            **self.meta,
        }


def _train_io(param_specs, extras, x_spec, y_spec):
    """Standard train-step input/output TensorSpec lists."""
    params = [TensorSpec(n, s, "f32", "param") for n, s in param_specs]
    moms = [TensorSpec(f"m_{n}", s, "f32", "momentum") for n, s in param_specs]
    ins = (params + moms + [x_spec, y_spec] + extras
           + [TensorSpec("lr", (), "f32", "lr")])
    outs = ([TensorSpec(n, s, "f32", "param") for n, s in param_specs]
            + [TensorSpec(f"m_{n}", s, "f32", "momentum")
               for n, s in param_specs]
            + [TensorSpec("loss", (), "f32", "loss"),
               TensorSpec("correct", (), "f32", "correct")])
    return ins, outs


def _eval_io(param_specs, x_spec, y_spec):
    params = [TensorSpec(n, s, "f32", "param") for n, s in param_specs]
    ins = params + [x_spec, y_spec]
    outs = [TensorSpec("loss", (), "f32", "loss"),
            TensorSpec("correct", (), "f32", "correct")]
    return ins, outs


def _b0(i):
    return TensorSpec(f"b0_{i}", (), "i32", "bias")


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------

def mlp_artifacts(arch: model.MlpArch, dp_pairs, tag=None) -> list:
    tag = tag or arch.name
    ps = model.mlp_param_specs(arch)
    xs = TensorSpec("x", (arch.batch, arch.n_in), "f32", "x")
    ys = TensorSpec("y", (arch.batch,), "i32", "y")
    h1, h2 = arch.hidden
    meta = {"model": "mlp",
            "arch": {"n_in": arch.n_in, "hidden": list(arch.hidden),
                     "n_out": arch.n_out, "batch": arch.batch},
            "sites": 2,
            # Per-arch tile edge: the TDP semantics (and the reference
            # backend's interpretation of them) depend on it; tiny test
            # archs override the global model.TILE.
            "tile": arch.tile}
    out = []

    ins, outs = _train_io(
        ps,
        [TensorSpec("mask0", (arch.batch, h1), "f32", "mask"),
         TensorSpec("mask1", (arch.batch, h2), "f32", "mask"),
         TensorSpec("scale0", (), "f32", "scale"),
         TensorSpec("scale1", (), "f32", "scale")],
        xs, ys)
    out.append(ArtifactSpec(f"{tag}_conv", model.mlp_train_step_conv(arch),
                            ins, outs, {**meta, "variant": "conv", "dp": []}))

    ins, outs = _eval_io(ps, xs, ys)
    out.append(ArtifactSpec(f"{tag}_eval", model.mlp_eval(arch), ins, outs,
                            {**meta, "variant": "eval", "dp": []}))

    pattern_extras = [_b0(0), _b0(1),
                      TensorSpec("scale0", (), "f32", "scale"),
                      TensorSpec("scale1", (), "f32", "scale")]
    for dp1, dp2 in dp_pairs:
        ins, outs = _train_io(ps, pattern_extras, xs, ys)
        out.append(ArtifactSpec(
            f"{tag}_rdp_{dp1}_{dp2}",
            model.mlp_train_step_rdp(arch, dp1, dp2), ins, outs,
            {**meta, "variant": "rdp", "dp": [dp1, dp2]}))
        out.append(ArtifactSpec(
            f"{tag}_tdp_{dp1}_{dp2}",
            model.mlp_train_step_tdp(arch, dp1, dp2), ins, outs,
            {**meta, "variant": "tdp", "dp": [dp1, dp2]}))
    return out


def lstm_artifacts(arch: model.LstmArch, dps, variants=("conv", "eval",
                                                        "rdp", "tdp"),
                   tag=None) -> list:
    tag = tag or f"{arch.name}b{arch.batch}"
    ps = model.lstm_param_specs(arch)
    xs = TensorSpec("x", (arch.batch, arch.seq), "i32", "x")
    ys = TensorSpec("y", (arch.batch, arch.seq), "i32", "y")
    L, H = arch.layers, arch.hidden
    meta = {"model": "lstm",
            "arch": {"vocab": arch.vocab, "hidden": H, "layers": L,
                     "seq": arch.seq, "batch": arch.batch},
            "sites": L,
            "tile": arch.tile}
    out = []

    if "conv" in variants:
        extras = ([TensorSpec(f"mask{i}", (arch.batch, H), "f32", "mask")
                   for i in range(L)]
                  + [TensorSpec(f"scale{i}", (), "f32", "scale")
                     for i in range(L)])
        ins, outs = _train_io(ps, extras, xs, ys)
        out.append(ArtifactSpec(f"{tag}_conv",
                                model.lstm_train_step_conv(arch), ins, outs,
                                {**meta, "variant": "conv", "dp": []}))
    if "eval" in variants:
        ins, outs = _eval_io(ps, xs, ys)
        out.append(ArtifactSpec(f"{tag}_eval", model.lstm_eval(arch), ins,
                                outs, {**meta, "variant": "eval", "dp": []}))
    for dp in dps:
        # LSTM bias extras are [seq] int32 *tracks* (one bias per
        # timestep), unlike the MLP's scalars: the coordinator re-draws
        # the bias every AD_TIME_WINDOW timesteps and a constant track
        # reproduces the legacy per-step behaviour bit-for-bit.
        extras = ([TensorSpec(f"b0_{i}", (arch.seq,), "i32", "bias")
                   for i in range(L)]
                  + [TensorSpec(f"scale{i}", (), "f32", "scale")
                     for i in range(L)])
        if "rdp" in variants:
            ins, outs = _train_io(ps, extras, xs, ys)
            out.append(ArtifactSpec(
                f"{tag}_rdp_{dp}", model.lstm_train_step_rdp(arch, dp),
                ins, outs, {**meta, "variant": "rdp", "dp": [dp] * L}))
        if "tdp" in variants:
            ins, outs = _train_io(ps, extras, xs, ys)
            out.append(ArtifactSpec(
                f"{tag}_tdp_{dp}", model.lstm_train_step_tdp(arch, dp),
                ins, outs, {**meta, "variant": "tdp", "dp": [dp] * L}))
    return out


def build_registry(which: str) -> list:
    D = DP_SUPPORT
    diag = [(d, d) for d in D]
    full = [(a, b) for a in D for b in D]
    arts = []

    # Tiny arch: fast CI / rust integration tests.
    tiny = model.MlpArch(hidden=(64, 64), n_in=32, n_out=10, batch=8,
                         tile=16)
    arts += mlp_artifacts(tiny, [(2, 2)], tag="mlptest")
    tiny_l = model.LstmArch(vocab=64, hidden=32, layers=2, seq=5,
                            batch=4, tile=16)
    arts += lstm_artifacts(tiny_l, [2], tag="lstmtest")

    if which in ("mlp", "all"):
        # Fig 4 arch: full dp-pair grid (asymmetric per-layer rates).
        arts += mlp_artifacts(model.MlpArch(hidden=(2048, 2048)), full)
        # Table I archs: shared-dp sampling (diagonal pairs).
        for hidden in [(1024, 64), (1024, 1024), (4096, 4096)]:
            arts += mlp_artifacts(model.MlpArch(hidden=hidden), diag)

    if which in ("lstm", "all"):
        # Table II timing at paper scale (H=1536~1500 — tile-aligned; see
        # DESIGN.md section 5) and convergence at reduced scale (Fig 5).
        arts += lstm_artifacts(
            model.LstmArch(vocab=8800, hidden=1536, layers=2), D)
        arts += lstm_artifacts(
            model.LstmArch(vocab=2048, hidden=256, layers=2), D)
        # Fig 6a: 3-layer PTB-like LSTM. Fig 6b: batch-size sweep (RDP only,
        # as in the paper's figure).
        arts += lstm_artifacts(
            model.LstmArch(vocab=10240, hidden=512, layers=3), D)
        for b in [25, 30, 35, 40]:
            arts += lstm_artifacts(
                model.LstmArch(vocab=10240, hidden=512, layers=3, batch=b),
                [1, 2, 4], variants=("conv", "rdp"))

    return arts


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(fn, arg_sds) -> str:
    lowered = jax.jit(fn).lower(*arg_sds)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--set", default="all", choices=["all", "mlp", "lstm",
                                                     "test"],
                    help="artifact subset to build")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the .hlo.txt already exists")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    registry = build_registry("all" if args.set == "test" else args.set)
    if args.set == "test":
        registry = [a for a in registry
                    if a.name.startswith(("mlptest", "lstmtest"))]
    # --only filters what gets LOWERED; the manifest always covers the full
    # registry so a partial rebuild never clobbers the index.
    arts = registry
    if args.only:
        arts = [a for a in arts if args.only in a.name]

    t_start = time.time()
    n_built = n_skipped = 0
    for a in arts:
        path = os.path.join(args.out, f"{a.name}.hlo.txt")
        if os.path.exists(path) and not args.force:
            n_skipped += 1
            continue
        t0 = time.time()
        text = to_hlo_text(a.fn, [t.sds() for t in a.inputs])
        with open(path, "w") as f:
            f.write(text)
        n_built += 1
        print(f"  [{n_built}] {a.name}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)", flush=True)

    manifest = {
        "version": 1,
        "dp_support": DP_SUPPORT,
        "momentum": model.MOMENTUM,
        "tile": model.TILE,
        "artifacts": [a.js() for a in registry],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"aot: {n_built} built, {n_skipped} cached, "
          f"{len(arts)} in manifest ({time.time() - t_start:.0f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
