//! Leveled stderr logger with wall-clock timestamps. Controlled by the
//! `AD_LOG` env var (error|warn|info|debug|trace; default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init_from_env() {
    INIT.call_once(|| {
        let lvl = match std::env::var("AD_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = t.as_secs();
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{h:02}:{m:02}:{s:02}.{:03} {tag}] {args}", t.subsec_millis());
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
