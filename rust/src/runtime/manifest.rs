//! `artifacts/manifest.json` loader — the contract between the AOT python
//! side and the Rust runtime — plus in-Rust synthetic manifest builders.
//!
//! Every executable's exact input/output tensor order, shapes, dtypes and
//! semantic kinds live here; the coordinator is generic over variants and
//! architectures because of it. The synthetic builders
//! ([`Manifest::builtin_test`], [`mlp_artifacts`], [`lstm_artifacts`])
//! produce byte-for-byte the same schema `aot.py` writes, so the
//! reference backend can execute without any artifacts directory and the
//! PJRT backend dispatches identically against the generated files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

/// Semantic role of a tensor in the train-step calling convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Param,
    Momentum,
    X,
    Y,
    Mask,
    Scale,
    Bias, // pattern bias scalar b0
    Lr,
    Loss,
    Correct,
}

impl Kind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "param" => Kind::Param,
            "momentum" => Kind::Momentum,
            "x" => Kind::X,
            "y" => Kind::Y,
            "mask" => Kind::Mask,
            "scale" => Kind::Scale,
            "bias" => Kind::Bias,
            "lr" => Kind::Lr,
            "loss" => Kind::Loss,
            "correct" => Kind::Correct,
            other => bail!("unknown tensor kind {other}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub kind: Kind,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub enum ArchMeta {
    Mlp { n_in: usize, hidden: Vec<usize>, n_out: usize, batch: usize },
    Lstm { vocab: usize, hidden: usize, layers: usize, seq: usize,
           batch: usize },
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: String,   // "mlp" | "lstm"
    pub variant: String, // "conv" | "eval" | "rdp" | "tdp"
    pub dp: Vec<usize>,
    pub sites: usize,
    /// Tile edge for the TDP pattern of this architecture (the paper's
    /// 32, our 128 at scale, 16 for the tiny test archs). Falls back to
    /// the manifest-global tile when an artifact entry omits it.
    pub tile: usize,
    pub arch: ArchMeta,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ArtifactMeta {
    pub fn n_params(&self) -> usize {
        self.inputs.iter().filter(|t| t.kind == Kind::Param).count()
    }

    pub fn param_metas(&self) -> Vec<&TensorMeta> {
        self.inputs.iter().filter(|t| t.kind == Kind::Param).collect()
    }

    pub fn batch(&self) -> usize {
        match &self.arch {
            ArchMeta::Mlp { batch, .. } => *batch,
            ArchMeta::Lstm { batch, .. } => *batch,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dp_support: Vec<usize>,
    pub momentum: f64,
    pub tile: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let name = j.get("name").and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor missing name"))?.to_string();
    let shape = j.get("shape").and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(
        j.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?;
    let kind = Kind::parse(
        j.get("kind").and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor {name} missing kind"))?)?;
    Ok(TensorMeta { name, shape, dtype, kind })
}

fn arch_meta(model: &str, j: &Json) -> Result<ArchMeta> {
    let u = |key: &str| -> Result<usize> {
        j.get(key).and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("arch missing {key}"))
    };
    Ok(match model {
        "mlp" => ArchMeta::Mlp {
            n_in: u("n_in")?,
            hidden: j.get("hidden").and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("mlp arch missing hidden"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            n_out: u("n_out")?,
            batch: u("batch")?,
        },
        "lstm" => ArchMeta::Lstm {
            vocab: u("vocab")?,
            hidden: u("hidden")?,
            layers: u("layers")?,
            seq: u("seq")?,
            batch: u("batch")?,
        },
        other => bail!("unknown model {other}"),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let global_tile =
            root.get("tile").and_then(Json::as_usize).unwrap_or(32);
        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a.get("name").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let model = a.get("model").and_then(Json::as_str)
                .unwrap_or("mlp").to_string();
            let meta = ArtifactMeta {
                file: a.get("file").and_then(Json::as_str)
                    .unwrap_or(&format!("{name}.hlo.txt")).to_string(),
                model: model.clone(),
                variant: a.get("variant").and_then(Json::as_str)
                    .unwrap_or("conv").to_string(),
                dp: a.get("dp").and_then(Json::as_arr).unwrap_or(&[])
                    .iter().filter_map(Json::as_usize).collect(),
                sites: a.get("sites").and_then(Json::as_usize).unwrap_or(0),
                tile: a.get("tile").and_then(Json::as_usize)
                    .unwrap_or(global_tile),
                arch: arch_meta(&model,
                                a.get("arch")
                                    .ok_or_else(|| anyhow!("missing arch"))?)?,
                inputs: a.get("inputs").and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing inputs"))?
                    .iter().map(tensor_meta).collect::<Result<_>>()?,
                outputs: a.get("outputs").and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing outputs"))?
                    .iter().map(tensor_meta).collect::<Result<_>>()?,
                name: name.clone(),
            };
            artifacts.insert(name, meta);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            dp_support: root.get("dp_support").and_then(Json::as_arr)
                .unwrap_or(&[]).iter().filter_map(Json::as_usize).collect(),
            momentum: root.get("momentum").and_then(Json::as_f64)
                .unwrap_or(0.9),
            tile: global_tile,
            artifacts,
        })
    }

    /// Assemble a manifest from in-Rust artifact metas (no files on disk;
    /// `hlo_path` then points at nonexistent files, which only the PJRT
    /// backend cares about).
    pub fn synthetic(artifacts: Vec<ArtifactMeta>) -> Manifest {
        let mut map = BTreeMap::new();
        for a in artifacts {
            map.insert(a.name.clone(), a);
        }
        Manifest {
            dir: PathBuf::new(),
            dp_support: vec![1, 2, 4, 8],
            momentum: 0.9,
            tile: 128,
            artifacts: map,
        }
    }

    /// The built-in hermetic registry: the `aot.py --set test` artifacts
    /// (`mlptest`, `lstmtest` — identical schema, so dispatch/naming
    /// agree with generated artifacts) plus two synthetic-data-sized
    /// archs (`mlpsyn` takes the 784-pixel MnistSyn images, `lstmsyn` a
    /// 64-token corpus) that only exist for artifact-free end-to-end
    /// training on the reference backend.
    pub fn builtin_test() -> Manifest {
        let mut arts = mlp_artifacts(
            &MlpArchSpec { tag: "mlptest".into(), n_in: 32,
                           hidden: [64, 64], n_out: 10, batch: 8,
                           tile: 16 },
            &[(2, 2)]);
        arts.extend(lstm_artifacts(
            &LstmArchSpec { tag: "lstmtest".into(), vocab: 64, hidden: 32,
                            layers: 2, seq: 5, batch: 4, tile: 16 },
            &[2]));
        // The syn archs carry the full {1,2,4}^2 dp grid so schedules can
        // target the paper's rate range (dp=4 covers p up to 0.75 — the
        // speedup bench sweeps 0.3/0.5/0.7). dp=4 divides every syn
        // tile-grid edge it masks (w1 784x64 and w2 64x64 at tile 16;
        // lstm wx 32x128 and wsoft 32x64 at tile 16).
        arts.extend(mlp_artifacts(
            &MlpArchSpec { tag: "mlpsyn".into(), n_in: 784,
                           hidden: [64, 64], n_out: 10, batch: 16,
                           tile: 16 },
            &[(1, 1), (1, 2), (1, 4), (2, 1), (2, 2), (2, 4), (4, 1),
              (4, 2), (4, 4)]));
        arts.extend(lstm_artifacts(
            &LstmArchSpec { tag: "lstmsyn".into(), vocab: 64, hidden: 32,
                            layers: 2, seq: 8, batch: 8, tile: 16 },
            &[1, 2, 4]));
        Manifest::synthetic(arts)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!("artifact '{name}' not in manifest \
                     ({} known)", self.artifacts.len())
        })
    }

    /// Path of an artifact's HLO text file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Artifact naming convention (mirrors aot.py): `<tag>_<variant>` or
    /// `<tag>_<variant>_<dp1>[_<dp2>...]`.
    pub fn artifact_name(tag: &str, variant: &str, dp: &[usize]) -> String {
        if dp.is_empty() {
            format!("{tag}_{variant}")
        } else {
            let dps: Vec<String> = dp.iter().map(|d| d.to_string()).collect();
            format!("{tag}_{variant}_{}", dps.join("_"))
        }
    }
}

// ---------------------------------------------------------------------------
// Synthetic artifact builders (mirror aot.py's registry functions)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MlpArchSpec {
    pub tag: String,
    pub n_in: usize,
    pub hidden: [usize; 2],
    pub n_out: usize,
    pub batch: usize,
    pub tile: usize,
}

#[derive(Clone, Debug)]
pub struct LstmArchSpec {
    pub tag: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub seq: usize,
    pub batch: usize,
    pub tile: usize,
}

fn t_f32(name: &str, shape: &[usize], kind: Kind) -> TensorMeta {
    TensorMeta { name: name.into(), shape: shape.to_vec(),
                 dtype: Dtype::F32, kind }
}

fn t_i32(name: &str, shape: &[usize], kind: Kind) -> TensorMeta {
    TensorMeta { name: name.into(), shape: shape.to_vec(),
                 dtype: Dtype::I32, kind }
}

/// Standard train-step input/output lists (mirrors aot.py `_train_io`):
/// inputs `params ++ m_<param> momenta ++ x, y ++ extras ++ lr`; outputs
/// `params ++ momenta ++ loss, correct`.
fn train_io(param_specs: &[(String, Vec<usize>)], x: TensorMeta,
            y: TensorMeta, extras: Vec<TensorMeta>)
            -> (Vec<TensorMeta>, Vec<TensorMeta>) {
    let params: Vec<TensorMeta> = param_specs
        .iter()
        .map(|(n, s)| t_f32(n, s, Kind::Param))
        .collect();
    let momenta: Vec<TensorMeta> = param_specs
        .iter()
        .map(|(n, s)| t_f32(&format!("m_{n}"), s, Kind::Momentum))
        .collect();
    let mut inputs = params.clone();
    inputs.extend(momenta.clone());
    inputs.push(x);
    inputs.push(y);
    inputs.extend(extras);
    inputs.push(t_f32("lr", &[], Kind::Lr));
    let mut outputs = params;
    outputs.extend(momenta);
    outputs.push(t_f32("loss", &[], Kind::Loss));
    outputs.push(t_f32("correct", &[], Kind::Correct));
    (inputs, outputs)
}

fn eval_io(param_specs: &[(String, Vec<usize>)], x: TensorMeta,
           y: TensorMeta) -> (Vec<TensorMeta>, Vec<TensorMeta>) {
    let mut inputs: Vec<TensorMeta> = param_specs
        .iter()
        .map(|(n, s)| t_f32(n, s, Kind::Param))
        .collect();
    inputs.push(x);
    inputs.push(y);
    let outputs = vec![t_f32("loss", &[], Kind::Loss),
                       t_f32("correct", &[], Kind::Correct)];
    (inputs, outputs)
}

fn b0_spec(i: usize) -> TensorMeta {
    t_i32(&format!("b0_{i}"), &[], Kind::Bias)
}

/// The full artifact family of one MLP arch: `_conv`, `_eval`, and one
/// `_rdp_<dp1>_<dp2>` + `_tdp_<dp1>_<dp2>` pair per dp pair (mirrors
/// aot.py `mlp_artifacts`).
pub fn mlp_artifacts(spec: &MlpArchSpec, dp_pairs: &[(usize, usize)])
                     -> Vec<ArtifactMeta> {
    let [h1, h2] = spec.hidden;
    let param_specs: Vec<(String, Vec<usize>)> = vec![
        ("w1".into(), vec![spec.n_in, h1]),
        ("b1".into(), vec![h1]),
        ("w2".into(), vec![h1, h2]),
        ("b2".into(), vec![h2]),
        ("w3".into(), vec![h2, spec.n_out]),
        ("b3".into(), vec![spec.n_out]),
    ];
    let xs = || t_f32("x", &[spec.batch, spec.n_in], Kind::X);
    let ys = || t_i32("y", &[spec.batch], Kind::Y);
    let arch = ArchMeta::Mlp { n_in: spec.n_in, hidden: vec![h1, h2],
                               n_out: spec.n_out, batch: spec.batch };
    let base = |name: String, variant: &str, dp: Vec<usize>,
                io: (Vec<TensorMeta>, Vec<TensorMeta>)| ArtifactMeta {
        file: format!("{name}.hlo.txt"),
        name,
        model: "mlp".into(),
        variant: variant.into(),
        dp,
        sites: 2,
        tile: spec.tile,
        arch: arch.clone(),
        inputs: io.0,
        outputs: io.1,
    };

    let mut out = Vec::new();
    let conv_extras = vec![
        t_f32("mask0", &[spec.batch, h1], Kind::Mask),
        t_f32("mask1", &[spec.batch, h2], Kind::Mask),
        t_f32("scale0", &[], Kind::Scale),
        t_f32("scale1", &[], Kind::Scale),
    ];
    out.push(base(format!("{}_conv", spec.tag), "conv", vec![],
                  train_io(&param_specs, xs(), ys(), conv_extras)));
    out.push(base(format!("{}_eval", spec.tag), "eval", vec![],
                  eval_io(&param_specs, xs(), ys())));
    for &(dp1, dp2) in dp_pairs {
        let extras = || vec![b0_spec(0), b0_spec(1),
                             t_f32("scale0", &[], Kind::Scale),
                             t_f32("scale1", &[], Kind::Scale)];
        out.push(base(format!("{}_rdp_{dp1}_{dp2}", spec.tag), "rdp",
                      vec![dp1, dp2],
                      train_io(&param_specs, xs(), ys(), extras())));
        out.push(base(format!("{}_tdp_{dp1}_{dp2}", spec.tag), "tdp",
                      vec![dp1, dp2],
                      train_io(&param_specs, xs(), ys(), extras())));
    }
    out
}

/// The artifact family of one LSTM arch: `_conv`, `_eval`, and one
/// `_rdp_<dp>` + `_tdp_<dp>` pair per divisor (equal-dp combos only;
/// mirrors aot.py `lstm_artifacts`).
pub fn lstm_artifacts(spec: &LstmArchSpec, dps: &[usize])
                      -> Vec<ArtifactMeta> {
    let (h, l) = (spec.hidden, spec.layers);
    let mut param_specs: Vec<(String, Vec<usize>)> =
        vec![("emb".into(), vec![spec.vocab, h])];
    for li in 0..l {
        param_specs.push((format!("wx{li}"), vec![h, 4 * h]));
        param_specs.push((format!("wh{li}"), vec![h, 4 * h]));
        param_specs.push((format!("bg{li}"), vec![4 * h]));
    }
    param_specs.push(("wsoft".into(), vec![h, spec.vocab]));
    param_specs.push(("bsoft".into(), vec![spec.vocab]));
    let xs = || t_i32("x", &[spec.batch, spec.seq], Kind::X);
    let ys = || t_i32("y", &[spec.batch, spec.seq], Kind::Y);
    let arch = ArchMeta::Lstm { vocab: spec.vocab, hidden: h, layers: l,
                                seq: spec.seq, batch: spec.batch };
    let base = |name: String, variant: &str, dp: Vec<usize>,
                io: (Vec<TensorMeta>, Vec<TensorMeta>)| ArtifactMeta {
        file: format!("{name}.hlo.txt"),
        name,
        model: "lstm".into(),
        variant: variant.into(),
        dp,
        sites: l,
        tile: spec.tile,
        arch: arch.clone(),
        inputs: io.0,
        outputs: io.1,
    };

    let mut out = Vec::new();
    let mut conv_extras = Vec::new();
    for i in 0..l {
        conv_extras.push(
            t_f32(&format!("mask{i}"), &[spec.batch, h], Kind::Mask));
    }
    for i in 0..l {
        conv_extras.push(t_f32(&format!("scale{i}"), &[], Kind::Scale));
    }
    out.push(base(format!("{}_conv", spec.tag), "conv", vec![],
                  train_io(&param_specs, xs(), ys(), conv_extras)));
    out.push(base(format!("{}_eval", spec.tag), "eval", vec![],
                  eval_io(&param_specs, xs(), ys())));
    for &dp in dps {
        let extras = || {
            // LSTM b0 biases are per-timestep tracks of shape [seq] (one
            // kept-residue per timestep, constant within each time
            // window) rather than the MLP's scalars — the step
            // interpreter groups equal consecutive entries into pattern
            // windows, so W=seq degenerates to a constant track and the
            // per-step behavior is unchanged.
            let mut e: Vec<TensorMeta> = (0..l)
                .map(|i| t_i32(&format!("b0_{i}"), &[spec.seq], Kind::Bias))
                .collect();
            for i in 0..l {
                e.push(t_f32(&format!("scale{i}"), &[], Kind::Scale));
            }
            e
        };
        out.push(base(format!("{}_rdp_{dp}", spec.tag), "rdp",
                      vec![dp; l],
                      train_io(&param_specs, xs(), ys(), extras())));
        out.push(base(format!("{}_tdp_{dp}", spec.tag), "tdp",
                      vec![dp; l],
                      train_io(&param_specs, xs(), ys(), extras())));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_covers_test_registry() {
        let m = Manifest::builtin_test();
        for name in ["mlptest_conv", "mlptest_eval", "mlptest_rdp_2_2",
                     "mlptest_tdp_2_2", "lstmtest_conv", "lstmtest_eval",
                     "lstmtest_rdp_2", "lstmtest_tdp_2", "mlpsyn_conv",
                     "mlpsyn_rdp_1_2", "lstmsyn_rdp_1", "lstmsyn_tdp_2",
                     "mlpsyn_rdp_4_4", "mlpsyn_tdp_2_4", "lstmsyn_rdp_4",
                     "lstmsyn_tdp_4"] {
            assert!(m.get(name).is_ok(), "missing {name}");
        }
        assert_eq!(m.tile, 128);
        assert_eq!(m.get("mlptest_conv").unwrap().tile, 16);
        assert!((m.momentum - 0.9).abs() < 1e-9);
        assert!(m.dp_support.contains(&2));
    }

    #[test]
    fn tiny_mlp_entry_shape() {
        let m = Manifest::builtin_test();
        let a = m.get("mlptest_conv").unwrap();
        assert_eq!(a.model, "mlp");
        assert_eq!(a.variant, "conv");
        assert_eq!(a.n_params(), 6);
        // inputs: 6 params + 6 momenta + x + y + 2 masks + 2 scales + lr
        assert_eq!(a.inputs.len(), 19);
        // outputs: 6 + 6 + loss + correct
        assert_eq!(a.outputs.len(), 14);
        let w1 = &a.inputs[0];
        assert_eq!(w1.name, "w1");
        assert_eq!(w1.shape, vec![32, 64]);
        assert_eq!(w1.kind, Kind::Param);
        assert_eq!(a.param_metas().len(), 6);
        assert_eq!(a.batch(), 8);
    }

    #[test]
    fn rdp_entry_has_bias_inputs() {
        let m = Manifest::builtin_test();
        let a = m.get("mlptest_rdp_2_2").unwrap();
        assert_eq!(a.dp, vec![2, 2]);
        let biases: Vec<_> =
            a.inputs.iter().filter(|t| t.kind == Kind::Bias).collect();
        assert_eq!(biases.len(), 2);
        assert_eq!(biases[0].dtype, Dtype::I32);
    }

    #[test]
    fn lstm_entry_layout_matches_aot() {
        let m = Manifest::builtin_test();
        let a = m.get("lstmtest_rdp_2").unwrap();
        // 9 params (emb + 3x2 cells + wsoft + bsoft), same momenta,
        // x, y, 2 b0 + 2 scales, lr.
        assert_eq!(a.n_params(), 9);
        assert_eq!(a.inputs.len(), 9 + 9 + 2 + 4 + 1);
        assert_eq!(a.inputs[0].name, "emb");
        assert_eq!(a.inputs[1].name, "wx0");
        assert_eq!(a.inputs[9].name, "m_emb");
        assert_eq!(a.dp, vec![2, 2]);
        assert_eq!(a.sites, 2);
        let eval = m.get("lstmtest_eval").unwrap();
        assert_eq!(eval.inputs.len(), 9 + 2);
        assert_eq!(eval.outputs.len(), 2);
    }

    #[test]
    fn naming_convention() {
        assert_eq!(Manifest::artifact_name("mlp2048x2048", "rdp", &[2, 4]),
                   "mlp2048x2048_rdp_2_4");
        assert_eq!(Manifest::artifact_name("x", "eval", &[]), "x_eval");
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::builtin_test();
        assert!(m.get("nonexistent").is_err());
    }

    #[test]
    fn json_loader_roundtrip() {
        // Pin the JSON-file path hermetically: write a one-artifact
        // manifest to a temp dir and load it back.
        let dir = std::env::temp_dir().join(format!(
            "ad-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
 "version": 1,
 "dp_support": [1, 2],
 "momentum": 0.9,
 "tile": 128,
 "artifacts": [
  {"name": "m_conv", "file": "m_conv.hlo.txt", "model": "mlp",
   "variant": "conv", "dp": [], "sites": 2, "tile": 16,
   "arch": {"n_in": 32, "hidden": [64, 64], "n_out": 10, "batch": 8},
   "inputs": [{"name": "w1", "shape": [32, 64], "dtype": "f32",
               "kind": "param"}],
   "outputs": [{"name": "loss", "shape": [], "dtype": "f32",
                "kind": "loss"}]}
 ]
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("m_conv").unwrap();
        assert_eq!(a.tile, 16, "per-artifact tile overrides global");
        assert_eq!(m.tile, 128);
        assert_eq!(a.inputs[0].shape, vec![32, 64]);
        assert_eq!(m.hlo_path(a), dir.join("m_conv.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
