//! Session-subsystem tests: checkpoint/resume bit-exactness, scheduler
//! fairness, crash isolation, and checkpoint round-trip properties.
//!
//! Hermetic: everything runs on the in-process host backends (reference
//! and structured-sparse) over the built-in synthetic manifest. The CI
//! matrix re-runs this suite under AD_THREADS={1,4} and both AD_BACKEND
//! values; sparse-kernel bit-stability across thread counts is pinned by
//! `tests/sparse_kernels.rs`, which is what makes the cross-thread-count
//! resume guarantee compose.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Trainer, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::{Backend, Executor, HostTensor, Manifest,
                              ReferenceBackend, Value};
use approx_dropout::service::checkpoint::Checkpoint;
use approx_dropout::service::{jobs::JobSpec, jobs::ModelKind,
                              jobs::ServiceConfig, run_jobs, JobStatus};
use approx_dropout::util::json;
use approx_dropout::util::rng::Rng;
use approx_dropout::util::testkit;

fn caches() -> Vec<(&'static str, ExecutorCache)> {
    vec![
        ("reference", ExecutorCache::reference(Manifest::builtin_test())),
        ("sparse", ExecutorCache::sparse(Manifest::builtin_test())),
    ]
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ad-service-{}-{tag}",
                                              std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn mlp_trainer(cache: &ExecutorCache, variant: Variant, rates: &[f64],
               data_n: usize, seed: u64) -> MlpTrainer {
    let schedule = Schedule::new(variant, rates, &[1, 2], true).unwrap();
    MlpTrainer::new(cache, "mlpsyn", schedule, data_n, 0.01, seed).unwrap()
}

fn lstm_trainer(cache: &ExecutorCache, variant: Variant, tokens: &[i32],
                seed: u64) -> LstmTrainer {
    let shared = variant != Variant::Conv;
    let schedule =
        Schedule::new(variant, &[0.5, 0.5], &[2], shared).unwrap();
    LstmTrainer::new(cache, "lstmtest", schedule, tokens, 0.5, seed)
        .unwrap()
}

fn param_bits<F: approx_dropout::coordinator::ModelFront>(
    tr: &Trainer<F>) -> Vec<Vec<u32>> {
    (0..tr.state.params.len())
        .map(|i| {
            tr.state.param_f32(i).unwrap()
                .iter().map(|x| x.to_bits()).collect()
        })
        .collect()
}

/// The acceptance property: train N, checkpoint, resume in a *fresh*
/// trainer, train M more — the resumed trajectory (losses, accuracies,
/// dispatch sequence, final parameter bits, lr) is identical to an
/// uninterrupted N+M run. Pinned on both hermetic backends for both
/// architectures, through an actual checkpoint file.
#[test]
fn resume_matches_uninterrupted_bit_for_bit() {
    let dir = tmp_dir("resume");
    let data = MnistSyn::generate(192, 3);
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    for (bname, cache) in caches() {
        for model in ["mlp", "lstm"] {
            for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
                let path = dir.join(format!("{bname}-{model}-{}.ckpt",
                                            variant.as_str()));
                type Traj = (Vec<(u64, f64, f64)>, Vec<String>,
                             Vec<Vec<u32>>);
                let (full, tail): (Traj, Traj) = if model == "mlp" {
                    let mut a = mlp_trainer(&cache, variant,
                                            &[0.25, 0.25], data.n, 11);
                    a.warmup().unwrap();
                    a.train_with(&data, 12).unwrap();
                    let full = (curve(&a.metrics),
                                a.metrics.dispatched.clone(),
                                param_bits(&a));

                    let mut b = mlp_trainer(&cache, variant,
                                            &[0.25, 0.25], data.n, 11);
                    b.warmup().unwrap();
                    b.train_with(&data, 6).unwrap();
                    b.save_checkpoint(&path).unwrap();

                    let mut c = mlp_trainer(&cache, variant,
                                            &[0.25, 0.25], data.n, 11);
                    c.resume_from(&path).unwrap();
                    c.warmup().unwrap();
                    assert_eq!(c.state.step, 6);
                    c.train_with(&data, 6).unwrap();
                    (full, (curve(&c.metrics),
                            c.metrics.dispatched.clone(),
                            param_bits(&c)))
                } else {
                    let mut a = lstm_trainer(&cache, variant,
                                             &corpus.train, 11);
                    a.warmup().unwrap();
                    a.train(12).unwrap();
                    let full = (curve(&a.metrics),
                                a.metrics.dispatched.clone(),
                                param_bits(&a));

                    let mut b = lstm_trainer(&cache, variant,
                                             &corpus.train, 11);
                    b.warmup().unwrap();
                    b.train(6).unwrap();
                    b.save_checkpoint(&path).unwrap();

                    let mut c = lstm_trainer(&cache, variant,
                                             &corpus.train, 11);
                    c.resume_from(&path).unwrap();
                    c.warmup().unwrap();
                    assert_eq!(c.state.step, 6);
                    c.train(6).unwrap();
                    (full, (curve(&c.metrics),
                            c.metrics.dispatched.clone(),
                            param_bits(&c)))
                };
                let ctx = format!("{bname}/{model}/{:?}", variant);
                assert_eq!(&full.0[6..], &tail.0[..],
                           "{ctx}: resumed losses must be bit-identical");
                assert_eq!(&full.1[6..], &tail.1[..],
                           "{ctx}: resumed dispatch must be identical");
                assert_eq!(full.2, tail.2,
                           "{ctx}: final params must be bit-identical");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn curve(m: &approx_dropout::coordinator::TrainMetrics)
         -> Vec<(u64, f64, f64)> {
    m.curve.iter().map(|p| (p.step, p.loss, p.acc)).collect()
}

/// Mid-window checkpoint round-trip: with a multi-step pattern hold
/// (`W = 2*seq`), a checkpoint taken while a carry is live
/// (`held_left > 0`) must resume bit-exactly — the held (dp, b0)
/// choices and the remaining hold count are trainer state. Also pins
/// that windowed runs are a distinct experiment: their checkpoint is
/// rejected by a default per-step trainer via the config hash.
#[test]
fn mid_window_checkpoint_roundtrip_is_bit_exact() {
    let dir = tmp_dir("midwin");
    let corpus = Corpus::generate(64, 4000, 400, 400, 19);
    for (bname, cache) in caches() {
        let mk = || {
            let schedule =
                Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true)
                    .unwrap();
            // lstmtest has seq=5; W=10 holds one (dp, b0) draw across
            // two consecutive steps.
            LstmTrainer::new_with_window(&cache, "lstmtest", schedule,
                                         &corpus.train, 0.5, 23,
                                         Some(10))
                .unwrap()
        };
        let mut a = mk();
        a.warmup().unwrap();
        a.train(8).unwrap();
        let full = curve(&a.metrics);

        let path = dir.join(format!("{bname}.ckpt"));
        let mut b = mk();
        b.warmup().unwrap();
        // 3 steps: the window opened at step 2 still owes one held
        // step, so this checkpoint carries a live mid-window hold.
        b.train(3).unwrap();
        b.save_checkpoint(&path).unwrap();

        let mut c = mk();
        c.resume_from(&path).unwrap();
        c.warmup().unwrap();
        assert_eq!(c.state.step, 3);
        c.train(5).unwrap();
        let tail = curve(&c.metrics);
        assert_eq!(&full[3..], &tail[..],
                   "{bname}: mid-window resume must be bit-identical");
        assert_eq!(param_bits(&a), param_bits(&c),
                   "{bname}: final params must be bit-identical");

        // Cross-policy resume is a config mismatch, not silent drift.
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
        let mut plain = LstmTrainer::new_with_window(
            &cache, "lstmtest", schedule, &corpus.train, 0.5, 23, None)
            .unwrap();
        assert!(plain.resume_from(&path).is_err(),
                "{bname}: windowed ckpt must not resume per-step");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// lr-decay driver state (lr, epochs_done) survives a checkpoint: an
/// interrupted run crossing epoch boundaries decays on the same steps as
/// an uninterrupted one.
#[test]
fn resume_preserves_lr_decay_trajectory() {
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    // Tiny corpus -> one BPTT window per epoch, so decay fires every
    // couple of steps (same construction as tests/driver.rs).
    let (batch, seq) = match &cache.manifest().get("lstmtest_conv")
        .unwrap().arch
    {
        approx_dropout::runtime::ArchMeta::Lstm { batch, seq, .. } =>
            (*batch, *seq),
        _ => panic!("lstmtest is not an LSTM"),
    };
    let corpus = Corpus::generate(64, batch * (seq + 2), 64, 64, 5);
    let mk = |seed| {
        let mut tr = lstm_trainer(&cache, Variant::Rdp, &corpus.train,
                                  seed);
        tr.lr_decay = 0.5;
        tr.decay_after = 0;
        tr
    };
    let mut a = mk(6);
    a.warmup().unwrap();
    a.train(10).unwrap();

    let dir = tmp_dir("lrdecay");
    let path = dir.join("l.ckpt");
    let mut b = mk(6);
    b.warmup().unwrap();
    b.train(5).unwrap();
    b.save_checkpoint(&path).unwrap();
    let mut c = mk(6);
    c.resume_from(&path).unwrap();
    assert_eq!(c.lr, b.lr, "decayed lr must round-trip bit-exactly");
    assert_eq!(c.epochs_done(), b.epochs_done());
    c.train(5).unwrap();
    let full = curve(&a.metrics);
    let tail = curve(&c.metrics);
    assert_eq!(&full[5..], &tail[..],
               "post-resume decay trajectory must match");
    assert_eq!(a.lr, c.lr);
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming against a different experiment configuration is rejected by
/// the config hash, and a doctored version field is rejected by the
/// format check.
#[test]
fn resume_rejects_config_and_version_mismatch() {
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let data = MnistSyn::generate(128, 4);
    let mut a = mlp_trainer(&cache, Variant::Rdp, &[0.25, 0.25], data.n, 1);
    a.warmup().unwrap();
    a.train_with(&data, 2).unwrap();
    let ckpt = a.checkpoint().unwrap();

    // Different rates -> different schedule -> different hash.
    let mut other =
        mlp_trainer(&cache, Variant::Rdp, &[0.5, 0.5], data.n, 1);
    let err = other.restore(&ckpt).unwrap_err();
    assert!(err.to_string().contains("config hash"), "{err}");
    // Different variant too.
    let mut conv =
        mlp_trainer(&cache, Variant::Conv, &[0.25, 0.25], data.n, 1);
    assert!(conv.restore(&ckpt).is_err());
    // Different seed too: the dataset is regenerated from it, so a
    // cross-seed resume would silently train on different data.
    let mut reseeded =
        mlp_trainer(&cache, Variant::Rdp, &[0.25, 0.25], data.n, 2);
    assert!(reseeded.restore(&ckpt).is_err());
    // Same config accepts.
    let mut same =
        mlp_trainer(&cache, Variant::Rdp, &[0.25, 0.25], data.n, 1);
    same.restore(&ckpt).unwrap();
    assert_eq!(same.state.step, 2);

    // Doctored version.
    let mut bad = ckpt.clone();
    bad.version = 99;
    assert!(same.restore(&bad).unwrap_err().to_string()
            .contains("version"));
}

/// Property: over random (variant, rates, support, seed, split) configs,
/// a checkpoint that round-trips through its JSON text restores into a
/// trajectory identical to the donor's continuation.
#[test]
fn checkpoint_roundtrip_property_over_random_configs() {
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let corpus = Corpus::generate(64, 3000, 300, 300, 2);
    testkit::check("ckpt_roundtrip", 6, |rng: &mut Rng| {
        let variant = *testkit::gen_choice(
            rng, &[Variant::Conv, Variant::Rdp, Variant::Tdp]);
        let rate = *testkit::gen_choice(rng, &[0.25, 0.5]);
        let seed = rng.next_u64() % 1000;
        let pre = testkit::gen_range(rng, 1, 5);
        let post = testkit::gen_range(rng, 1, 4);
        let shared = variant != Variant::Conv;
        let mk = |s| {
            // lstmtest artifacts cover dp=2 only (builtin registry).
            let schedule =
                Schedule::new(variant, &[rate, rate], &[2], shared)
                    .unwrap();
            LstmTrainer::new(&cache, "lstmtest", schedule, &corpus.train,
                             0.5, s).unwrap()
        };
        let mut donor = mk(seed);
        donor.warmup().unwrap();
        donor.train(pre).unwrap();
        // Round-trip through the serialized text form.
        let text = donor.checkpoint().unwrap().to_json().pretty();
        let back =
            Checkpoint::from_json(&json::parse(&text).unwrap()).unwrap();
        let mut resumed = mk(seed);
        resumed.restore(&back).unwrap();
        donor.train(post).unwrap();
        resumed.train(post).unwrap();
        let d: Vec<f64> =
            donor.metrics.curve[pre..].iter().map(|p| p.loss).collect();
        let r: Vec<f64> =
            resumed.metrics.curve.iter().map(|p| p.loss).collect();
        assert_eq!(d, r, "variant {variant:?} rate {rate} pre {pre}");
        assert_eq!(param_bits(&donor), param_bits(&resumed));
    });
}

/// Scheduler fairness: more jobs than slots, everything queued finishes,
/// concurrency never exceeds the slot count, and outcomes come back in
/// manifest order.
#[test]
fn scheduler_runs_all_jobs_within_slot_budget() {
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let mk = |name: &str, seed: u64| {
        let mut j = JobSpec::named(name);
        j.rates = vec![0.25, 0.25];
        j.steps = 6;
        j.seed = seed;
        j.n_train = 128;
        j.n_test = 64;
        j
    };
    let specs = vec![mk("a", 1), mk("b", 2), mk("c", 3), mk("d", 4)];
    for slots in [1, 2] {
        let cfg = ServiceConfig {
            slots,
            tick_steps: 2,
            ..ServiceConfig::default()
        };
        let report = run_jobs(&cache, &specs, &cfg).unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert!(report.peak_slots <= slots,
                "peak {} > slots {slots}", report.peak_slots);
        for (o, s) in report.outcomes.iter().zip(&specs) {
            assert_eq!(o.name, s.name, "manifest order preserved");
            assert_eq!(o.status, JobStatus::Done, "{}: {:?}", o.name,
                       o.status);
            assert_eq!(o.steps_done, 6);
            assert!(o.eval.is_some());
            // 3 train ticks (6 steps / quantum 2) + setup + eval holds.
            assert_eq!(o.ticks, 5);
        }
        assert!(report.all_ok());
    }
}

/// Identical jobs produce identical trajectories no matter how the fleet
/// interleaves them — per-session determinism survives concurrency.
#[test]
fn concurrent_jobs_are_trajectory_deterministic() {
    let dir = tmp_dir("det");
    let mk = |name: &str| {
        let mut j = JobSpec::named(name);
        j.rates = vec![0.25, 0.25];
        j.steps = 5;
        j.seed = 42;
        j.n_train = 128;
        j.n_test = 64;
        j
    };
    let specs = vec![mk("x"), mk("y"), mk("z")];
    for (_, cache) in caches() {
        let cfg = ServiceConfig {
            slots: 3,
            tick_steps: 1,
            out_dir: Some(dir.clone()),
            ..ServiceConfig::default()
        };
        let report = run_jobs(&cache, &specs, &cfg).unwrap();
        assert!(report.all_ok());
        let losses: Vec<f64> = report
            .outcomes
            .iter()
            .map(|o| o.final_loss)
            .collect();
        assert_eq!(losses[0].to_bits(), losses[1].to_bits());
        assert_eq!(losses[0].to_bits(), losses[2].to_bits());
        // Reports landed and parse.
        for o in &report.outcomes {
            let p = o.report_path.as_ref().expect("report written");
            let v = json::parse(
                std::fs::read_to_string(p).unwrap().trim()).unwrap();
            assert_eq!(v.get("job").unwrap().as_str(),
                       Some(o.name.as_str()));
            assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 5);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Crash isolation

/// Wraps the reference backend; executors for artifacts whose name
/// contains `victim` panic on their `calls_before_panic`-th run.
#[derive(Debug)]
struct SabotageBackend {
    inner: ReferenceBackend,
    victim: &'static str,
}

struct SabotageExe {
    inner: Arc<dyn Executor>,
    calls: AtomicUsize,
}

impl Executor for SabotageExe {
    fn meta(&self) -> &approx_dropout::runtime::ArtifactMeta {
        self.inner.meta()
    }

    fn run_raw(&self, _inputs: &[&Value]) -> Result<Vec<Value>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        panic!("injected step panic");
    }
}

impl Backend for SabotageBackend {
    fn name(&self) -> &'static str {
        "sabotage"
    }

    fn compile(&self, manifest: &Manifest, name: &str)
               -> Result<Arc<dyn Executor>> {
        let inner = self.inner.compile(manifest, name)?;
        if name.contains(self.victim) {
            Ok(Arc::new(SabotageExe { inner,
                                      calls: AtomicUsize::new(0) }))
        } else {
            Ok(inner)
        }
    }

    fn upload(&self, t: &HostTensor) -> Result<Value> {
        self.inner.upload(t)
    }
}

/// A job whose backend panics mid-step is quarantined; its siblings run
/// to completion over the same shared cache (extends the PR 3 cache
/// poison-recovery to whole sessions).
#[test]
fn crash_isolation_quarantines_only_the_panicking_job() {
    let cache = ExecutorCache::new(
        Arc::new(SabotageBackend {
            inner: ReferenceBackend::new(),
            victim: "_tdp",
        }),
        Manifest::builtin_test(),
    );
    let mk = |name: &str, variant: Variant| {
        let mut j = JobSpec::named(name);
        j.variant = variant;
        j.rates = vec![0.25, 0.25];
        j.steps = 6;
        j.seed = 3;
        j.n_train = 128;
        j.n_test = 64;
        j
    };
    let specs = vec![
        mk("healthy-conv", Variant::Conv),
        mk("victim-tdp", Variant::Tdp),
        mk("healthy-rdp", Variant::Rdp),
    ];
    let cfg = ServiceConfig {
        slots: 2,
        tick_steps: 2,
        ..ServiceConfig::default()
    };
    let report = run_jobs(&cache, &specs, &cfg).unwrap();
    let by_name = |n: &str| {
        report.outcomes.iter().find(|o| o.name == n).unwrap()
    };
    match &by_name("victim-tdp").status {
        JobStatus::Failed(why) => {
            assert!(why.contains("panic"), "quarantine reason: {why}");
            assert!(why.contains("injected step panic"), "{why}");
        }
        s => panic!("victim should fail, got {s:?}"),
    }
    assert_eq!(by_name("healthy-conv").status, JobStatus::Done);
    assert_eq!(by_name("healthy-rdp").status, JobStatus::Done);
    assert_eq!(by_name("healthy-rdp").steps_done, 6);
    assert!(!report.all_ok());
}

/// The crash-recovery loop end to end: serve a fleet with checkpointing,
/// then serve the *same manifest again* — every job resumes from its
/// final checkpoint and completes immediately, trajectory intact.
#[test]
fn rerunning_the_fleet_resumes_from_checkpoints() {
    let dir = tmp_dir("fleet-resume");
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let mk = |steps: usize| {
        let mut j = JobSpec::named("resumer");
        j.model = ModelKind::Lstm;
        j.tag = "lstmtest".into();
        j.variant = Variant::Rdp;
        j.rates = vec![0.5, 0.5];
        j.support = vec![2];
        j.steps = steps;
        j.lr = 0.5;
        j.seed = 8;
        j.tokens = 4000;
        j
    };
    let cfg = ServiceConfig {
        slots: 1,
        tick_steps: 3,
        checkpoint_every: 3,
        ckpt_dir: Some(dir.clone()),
        out_dir: None,
    };
    // Phase 1: run to step 6 ("preemption" = the fleet simply ends).
    let r1 = run_jobs(&cache, &[mk(6)], &cfg).unwrap();
    assert!(r1.all_ok());
    assert!(dir.join("resumer.ckpt").exists());
    // Phase 2: same job, target 12 — resumes at 6, runs 6 more.
    let r2 = run_jobs(&cache, &[mk(12)], &cfg).unwrap();
    assert!(r2.all_ok());
    let o = &r2.outcomes[0];
    assert_eq!(o.resumed_at, Some(6));
    assert_eq!(o.steps_done, 12);
    // The stitched trajectory equals one uninterrupted 12-step run.
    let mut solo = lstm_trainer(&cache, Variant::Rdp,
                                &Corpus::generate(64, 4000, 400, 400, 8)
                                    .train, 8);
    solo.warmup().unwrap();
    solo.train(12).unwrap();
    assert_eq!(solo.metrics.curve.last().unwrap().loss.to_bits(),
               o.final_loss.to_bits(),
               "fleet-resumed trajectory must equal the solo run");
    // Phase 3: already complete — nothing to do, still Done.
    let r3 = run_jobs(&cache, &[mk(12)], &cfg).unwrap();
    assert_eq!(r3.outcomes[0].steps_done, 12);
    assert_eq!(r3.outcomes[0].resumed_at, Some(12));
    assert!(r3.all_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: rerunning an already-complete fleet used to clobber
/// REPORT_<name>.json with an empty curve and a NaN (-> null) final
/// loss, because the resumed session's metrics start empty and zero new
/// steps run. The rerun must preserve the completed report byte for
/// byte and still report an honest (finite) final loss.
#[test]
fn rerun_of_completed_fleet_preserves_report() {
    let dir = tmp_dir("rerun-report");
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let mk = || {
        let mut j = JobSpec::named("keeper");
        j.rates = vec![0.25, 0.25];
        j.steps = 4;
        j.seed = 6;
        j.n_train = 128;
        j.n_test = 64;
        j
    };
    let cfg = ServiceConfig {
        slots: 1,
        tick_steps: 2,
        checkpoint_every: 0,
        ckpt_dir: Some(dir.clone()),
        out_dir: Some(dir.clone()),
    };
    let r1 = run_jobs(&cache, &[mk()], &cfg).unwrap();
    assert!(r1.all_ok());
    let path = r1.outcomes[0].report_path.clone().expect("report written");
    let before = std::fs::read_to_string(&path).unwrap();
    let v = json::parse(before.trim()).unwrap();
    assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 4,
               "first run records the full curve");
    assert!(v.get("final_loss").unwrap().as_f64().is_some());

    // Rerun the same manifest: resumes complete, trains zero new steps.
    let r2 = run_jobs(&cache, &[mk()], &cfg).unwrap();
    assert!(r2.all_ok());
    let o = &r2.outcomes[0];
    assert_eq!(o.resumed_at, Some(4));
    assert_eq!(o.steps_done, 4);
    assert!(o.final_loss.is_finite(),
            "rerun reports the eval loss, not NaN");
    assert_eq!(o.report_path.as_deref(), Some(path.as_path()),
               "rerun still points at the (preserved) report");
    let after = std::fs::read_to_string(&path).unwrap();
    assert_eq!(before, after, "rerun must not clobber the report");
    std::fs::remove_dir_all(&dir).ok();
}
