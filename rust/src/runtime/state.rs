//! Training state: parameters + momenta held as backend-resident
//! [`Value`]s end-to-end.
//!
//! Perf-critical design (EXPERIMENTS.md section Perf): a train step's
//! outputs come back as backend values; feeding the same values back as
//! the next step's inputs avoids any host-side reshuffling of the
//! (possibly hundreds of MB) parameter state. On PJRT those values are
//! XLA literals (`decompose_tuple` is zero-copy), so the only per-step
//! copies left are PJRT's own host->device transfers; on the reference
//! backend they are plain host buffers.

use anyhow::{bail, Result};

use crate::runtime::backend::{Backend, Executor, HostTensor, Value};
use crate::runtime::manifest::{ArtifactMeta, Kind, TensorMeta};
use crate::util::rng::Rng;

/// One eval dispatch's full result set: the batch aggregates plus the
/// per-example vectors (see [`TrainState::infer_step`]).
#[derive(Clone, Debug)]
pub struct InferOut {
    /// Mean loss over the batch (same scalar `eval_step` returns).
    pub loss: f64,
    /// Correct count over the batch.
    pub correct: f64,
    /// Per-example loss, `[batch]`.
    pub ex_loss: Vec<f32>,
    /// Per-example correct count (MLP: 0/1 flag; LSTM: correct tokens in
    /// the track), `[batch]`.
    pub ex_correct: Vec<f32>,
}

pub struct TrainState {
    pub params: Vec<Value>,
    pub momenta: Vec<Value>,
    /// Manifest metadata of the params (name/shape), same order.
    pub metas: Vec<TensorMeta>,
    /// Cumulative training iterations applied.
    pub step: u64,
}

impl TrainState {
    /// Initialize from an artifact's param metas:
    /// * 2-D weights: Glorot-uniform  U(+-sqrt(6 / (fan_in + fan_out)))
    /// * embeddings (name "emb"): U(-0.1, 0.1) (Zaremba-style)
    /// * 1-D biases: zeros; momenta: zeros.
    ///
    /// The RNG draw order is identical for every backend (draws happen on
    /// host buffers before upload), so a fixed seed produces the same
    /// trajectory modulo backend float rounding — and the exact same
    /// downstream dispatch sequence.
    pub fn init(meta: &ArtifactMeta, rng: &mut Rng, backend: &dyn Backend)
                -> Result<TrainState> {
        let mut params = Vec::new();
        let mut metas = Vec::new();
        for t in meta.inputs.iter().filter(|t| t.kind == Kind::Param) {
            let n = t.elements();
            let data: Vec<f32> = if t.shape.len() == 2 {
                if t.name == "emb" {
                    (0..n).map(|_| rng.uniform(-0.1, 0.1) as f32).collect()
                } else {
                    let limit =
                        (6.0 / (t.shape[0] + t.shape[1]) as f64).sqrt();
                    (0..n).map(|_| rng.uniform(-limit, limit) as f32)
                        .collect()
                }
            } else {
                vec![0.0; n]
            };
            params.push(
                backend.ingest(HostTensor::f32(&t.shape, data))?);
            metas.push(t.clone());
        }
        let momenta = metas
            .iter()
            .map(|t| backend.ingest(
                HostTensor::f32(&t.shape, vec![0.0; t.elements()])))
            .collect::<Result<_>>()?;
        Ok(TrainState { params, momenta, metas, step: 0 })
    }

    /// Eval-only state from already-materialized parameter tensors (the
    /// inference registry's restore path: checkpoint params, no schedule,
    /// no RNG). `momenta` is left empty — [`TrainState::step`] on such a
    /// state fails its output-count check loudly; only the eval entry
    /// points ([`TrainState::eval_step`], [`TrainState::infer_step`]) are
    /// meaningful.
    pub fn eval_only(metas: Vec<TensorMeta>, params: Vec<Value>, step: u64)
                     -> Result<TrainState> {
        if metas.len() != params.len() {
            bail!("eval-only state: {} metas for {} params", metas.len(),
                  params.len());
        }
        Ok(TrainState { params, momenta: Vec::new(), metas, step })
    }

    /// Run one train step: inputs are `params ++ momenta ++ tail` (tail =
    /// x, y, variant extras, lr in manifest order). The output values
    /// replace the state in place. Returns (loss, correct).
    ///
    /// This is the fused single-thread path: forward, backward, and the
    /// SGD apply all happen inside the executable. The data-parallel
    /// path bypasses it — `Executor::run_grads` emits per-shard raw
    /// gradients against the same input list, and the sharded driver
    /// owns reduction and the SGD apply (`coordinator::driver`).
    pub fn step(&mut self, exe: &dyn Executor, tail: &[Value])
                -> Result<(f64, f64)> {
        let n = self.params.len();
        let refs: Vec<&Value> = self
            .params
            .iter()
            .chain(self.momenta.iter())
            .chain(tail.iter())
            .collect();
        let mut outputs = exe.run_raw(&refs)?;
        if outputs.len() != 2 * n + 2 {
            bail!("expected {} outputs, got {}", 2 * n + 2, outputs.len());
        }
        let correct = outputs.pop().unwrap().scalar_f64()?;
        let loss = outputs.pop().unwrap().scalar_f64()?;
        let mut it = outputs.into_iter();
        for p in self.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for m in self.momenta.iter_mut() {
            *m = it.next().unwrap();
        }
        self.step += 1;
        Ok((loss, correct))
    }

    /// Run one eval-graph batch against a borrowed executor: inputs are
    /// `params ++ extra` (extra = x, y in manifest order), outputs are the
    /// (loss, correct) scalars. State is untouched — eval graphs are
    /// dropout-free forward passes.
    pub fn eval_step(&self, exe: &dyn Executor, extra: &[Value])
                     -> Result<(f64, f64)> {
        let mut refs = self.param_refs();
        for v in extra {
            refs.push(v);
        }
        let out = exe.run_raw(&refs)?;
        if out.len() < 2 {
            bail!("eval graph returned {} outputs, expected at least 2",
                  out.len());
        }
        Ok((out[0].scalar_f64()?, out[1].scalar_f64()?))
    }

    /// Run one eval-graph batch and return the per-example results the
    /// hermetic interpreters emit alongside the aggregates: `ex_loss[i]` /
    /// `ex_correct[i]` describe example `i` of the batch (MLP: one image;
    /// LSTM: one seq-token track, loss = mean nll over the track). Fails
    /// loudly on backends whose eval graphs return aggregates only (the
    /// AOT PJRT graphs) — the inference service requires per-example
    /// outputs and must not fake them by splitting aggregates.
    pub fn infer_step(&self, exe: &dyn Executor, extra: &[Value])
                      -> Result<InferOut> {
        let mut refs = self.param_refs();
        for v in extra {
            refs.push(v);
        }
        let out = exe.run_raw(&refs)?;
        if out.len() < 4 {
            bail!("eval graph returned {} outputs, but per-example \
                   inference needs 4 (loss, correct, ex_loss, ex_correct) \
                   — this backend's eval graphs expose batch aggregates \
                   only; run the inference service on a hermetic backend \
                   (AD_BACKEND=reference|sparse)", out.len());
        }
        Ok(InferOut {
            loss: out[0].scalar_f64()?,
            correct: out[1].scalar_f64()?,
            ex_loss: out[2].to_f32()?,
            ex_correct: out[3].to_f32()?,
        })
    }

    /// References to the parameter values (eval-graph inputs).
    pub fn param_refs(&self) -> Vec<&Value> {
        self.params.iter().collect()
    }

    /// Copy one parameter back to host (tests / inspection).
    pub fn param_f32(&self, i: usize) -> Result<Vec<f32>> {
        self.params[i].to_f32()
    }

    /// Total parameter count (diagnostics).
    pub fn n_elements(&self) -> usize {
        self.metas.iter().map(|t| t.elements()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::reference::ReferenceBackend;

    fn manifest() -> Manifest {
        // Hermetic: the built-in synthetic manifest mirrors the aot.py
        // `--set test` registry, no artifacts directory needed.
        Manifest::builtin_test()
    }

    #[test]
    fn init_shapes_match_manifest() {
        let m = manifest();
        let meta = m.get("mlptest_conv").unwrap();
        let mut rng = Rng::new(0);
        let be = ReferenceBackend::new();
        let st = TrainState::init(meta, &mut rng, &be).unwrap();
        assert_eq!(st.params.len(), 6);
        assert_eq!(st.metas[0].shape, vec![32, 64]);
        assert_eq!(st.metas[1].shape, vec![64]);
        // biases zero, weights nonzero
        assert!(st.param_f32(1).unwrap().iter().all(|&v| v == 0.0));
        assert!(st.param_f32(0).unwrap().iter().any(|&v| v != 0.0));
        assert_eq!(st.n_elements(), 32 * 64 + 64 + 64 * 64 + 64 + 64 * 10
                   + 10);
    }

    #[test]
    fn glorot_bounds() {
        let m = manifest();
        let meta = m.get("mlptest_conv").unwrap();
        let mut rng = Rng::new(1);
        let be = ReferenceBackend::new();
        let st = TrainState::init(meta, &mut rng, &be).unwrap();
        let limit = (6.0 / (32 + 64) as f64).sqrt() as f32;
        let w1 = st.param_f32(0).unwrap();
        assert!(w1.iter().all(|&v| v.abs() <= limit));
        let max = w1.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(max > 0.8 * limit);
    }

    #[test]
    fn init_draw_order_is_backend_independent() {
        // Same seed -> bit-identical init through any backend: the draws
        // happen on host buffers before upload.
        let m = manifest();
        let meta = m.get("lstmtest_conv").unwrap();
        let be = ReferenceBackend::new();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = TrainState::init(meta, &mut r1, &be).unwrap();
        let b = TrainState::init(meta, &mut r2, &be).unwrap();
        for i in 0..a.params.len() {
            assert_eq!(a.param_f32(i).unwrap(), b.param_f32(i).unwrap());
        }
        // Both RNGs end in the same state.
        assert_eq!(r1.next_u64(), r2.next_u64());
    }
}
