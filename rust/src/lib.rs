//! # approx-dropout
//!
//! Production-grade reproduction of **"Approximate Random Dropout for DNN
//! training acceleration in GPGPU"** (Song, Wang, Yu, Huang, Peng, Jiang —
//! 2018) on a Rust + JAX + Pallas three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): compact/tiled
//!   matmuls whose BlockSpecs fetch only kept data.
//! * **L2** — JAX train-step graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text, one executable per `(model, variant, dp)`.
//! * **L3** — this crate: the coordinator that samples dropout patterns
//!   from the searched distribution K and drives PJRT.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for measured paper-vs-repro results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod patterns;
pub mod runtime;
pub mod search;
pub mod util;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory: `$AD_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts")
        })
}
