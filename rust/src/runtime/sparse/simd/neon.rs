//! NEON microkernels (aarch64). 4 f32 lanes, 2x unrolled — 8 elements
//! per iteration — with `vfmaq` doing the multiply-add in one rounding.
//! NEON is architecturally mandatory on aarch64, but selection still
//! goes through `is_aarch64_feature_detected!` (see `simd::detected`)
//! so the safety argument is uniform across arches.
//!
//! Determinism mirrors the AVX2 implementation: fixed lane/unroll
//! order, fixed `dot_acc` reduction order, `mul_add` scalar tails.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::{
    vaddq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32,
};

use super::Microkernel;

pub static NEON: Microkernel = Microkernel {
    name: "neon",
    axpy: axpy_shim,
    axpy2: axpy2_shim,
    dot_acc: dot_acc_shim,
};

// Plain `unsafe fn` shims — same rationale as in `x86.rs`.

/// # Safety
/// As [`axpy`].
unsafe fn axpy_shim(a: f32, x: *const f32, y: *mut f32, n: usize) {
    axpy(a, x, y, n)
}

/// # Safety
/// As [`axpy2`].
unsafe fn axpy2_shim(a0: f32, x0: *const f32, a1: f32, x1: *const f32,
                     y: *mut f32, n: usize) {
    axpy2(a0, x0, a1, x1, y, n)
}

/// # Safety
/// As [`dot_acc`].
unsafe fn dot_acc_shim(init: f32, x: *const f32, y: *const f32, n: usize)
                       -> f32 {
    dot_acc(init, x, y, n)
}

const W: usize = 4;

/// `y[i] += a * x[i]` — each element gets `fma(a, x[i], y[i])`.
///
/// # Safety
/// `x`/`y` valid for `n` reads / read-writes; NEON present.
#[target_feature(enable = "neon")]
unsafe fn axpy(a: f32, x: *const f32, y: *mut f32, n: usize) {
    let va = vdupq_n_f32(a);
    let mut i = 0;
    while i + 2 * W <= n {
        let y0 = vfmaq_f32(vld1q_f32(y.add(i)), va, vld1q_f32(x.add(i)));
        let y1 = vfmaq_f32(vld1q_f32(y.add(i + W)), va,
                           vld1q_f32(x.add(i + W)));
        vst1q_f32(y.add(i), y0);
        vst1q_f32(y.add(i + W), y1);
        i += 2 * W;
    }
    if i + W <= n {
        let y0 = vfmaq_f32(vld1q_f32(y.add(i)), va, vld1q_f32(x.add(i)));
        vst1q_f32(y.add(i), y0);
        i += W;
    }
    while i < n {
        *y.add(i) = a.mul_add(*x.add(i), *y.add(i));
        i += 1;
    }
}

/// `y[i] += a0 * x0[i] + a1 * x1[i]` as nested FMAs — bit-identical to
/// two sequential `axpy` passes.
///
/// # Safety
/// `x0`/`x1`/`y` valid for `n` reads / read-writes; NEON present.
#[target_feature(enable = "neon")]
unsafe fn axpy2(a0: f32, x0: *const f32, a1: f32, x1: *const f32,
                y: *mut f32, n: usize) {
    let v0 = vdupq_n_f32(a0);
    let v1 = vdupq_n_f32(a1);
    let mut i = 0;
    while i + W <= n {
        let t = vfmaq_f32(vld1q_f32(y.add(i)), v0, vld1q_f32(x0.add(i)));
        let t = vfmaq_f32(t, v1, vld1q_f32(x1.add(i)));
        vst1q_f32(y.add(i), t);
        i += W;
    }
    while i < n {
        let t = a0.mul_add(*x0.add(i), *y.add(i));
        *y.add(i) = a1.mul_add(*x1.add(i), t);
        i += 1;
    }
}

/// `init + Σ x[i] * y[i]`: two independent 4-lane FMA accumulators,
/// fixed-order reduction (acc0 + acc1 elementwise, lanes 0..3 summed
/// ascending onto `init`, scalar tail last).
///
/// # Safety
/// `x`/`y` valid for `n` reads; NEON present.
#[target_feature(enable = "neon")]
unsafe fn dot_acc(init: f32, x: *const f32, y: *const f32, n: usize)
                  -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 2 * W <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(x.add(i)), vld1q_f32(y.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(x.add(i + W)),
                         vld1q_f32(y.add(i + W)));
        i += 2 * W;
    }
    if i + W <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(x.add(i)), vld1q_f32(y.add(i)));
        i += W;
    }
    let mut lanes = [0f32; W];
    vst1q_f32(lanes.as_mut_ptr(), vaddq_f32(acc0, acc1));
    let mut acc = init;
    for l in lanes {
        acc += l;
    }
    while i < n {
        acc = (*x.add(i)).mul_add(*y.add(i), acc);
        i += 1;
    }
    acc
}
